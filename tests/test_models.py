"""Per-architecture smoke tests (task requirement: reduced same-family
config, one forward/train step on CPU, shapes + no NaNs) plus the serving
invariant prefill+decode == full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build, synth_batch, RunConfig

RC = RunConfig(param_dtype="float32", compute_dtype="float32", remat=False,
               loss_chunk=32, attn_q_chunk=16, attn_k_chunk=16)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = configs.get_smoke(arch)
    model = build(cfg, RC)
    params, logical = model.init(jax.random.PRNGKey(0))
    batch = synth_batch(model, jax.random.PRNGKey(1), 32, 2, "train")
    loss, grads = jax.jit(jax.value_and_grad(model.loss_fn))(params, batch)
    assert np.isfinite(float(loss))
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))
    # logical spec tree mirrors the params tree
    assert jax.tree.structure(jax.tree.map(lambda x: 0, params)) == \
        jax.tree.structure(jax.tree.map(lambda x: 0, logical,
                                        is_leaf=lambda x: isinstance(x, tuple)))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_serve_roundtrip(arch):
    cfg = configs.get_smoke(arch)
    model = build(cfg, RC)
    params, _ = model.init(jax.random.PRNGKey(0))
    b = synth_batch(model, jax.random.PRNGKey(1), 16, 2, "prefill")
    logits, cache = model.prefill(params, b, max_seq=24)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = model.decode_step(params, tok, cache,
                                        jnp.asarray(16, jnp.int32))
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch", ["stablelm-3b", "mamba2-130m", "qwen3-32b",
                                  "zamba2-1.2b", "mixtral-8x7b"])
def test_prefill_decode_matches_full_forward(arch):
    """logits(prefill(t_0..t_{n-1})) then decode(t_n) must equal the last
    logits of a full forward over t_0..t_n — THE serving correctness
    invariant (cache semantics, positions, masks).

    MoE note: capacity-based routing drops depend on the step's token count,
    so the invariant only holds drop-free — we raise capacity_factor for the
    check (verified: cf=1.25 diverges by ~0.57, cf=8 agrees to 2e-6)."""
    import dataclasses
    rc = dataclasses.replace(RC, capacity_factor=8.0)
    cfg = configs.get_smoke(arch)
    model = build(cfg, rc)
    params, _ = model.init(jax.random.PRNGKey(0))
    L = 12
    toks = jax.random.randint(jax.random.PRNGKey(7), (2, L + 1), 0, cfg.vocab,
                              jnp.int32)
    # full forward over L+1 tokens
    full_logits, _ = model.prefill(params, {"tokens": toks}, max_seq=L + 1)
    # prefill L then decode token L
    logits_p, cache = model.prefill(params, {"tokens": toks[:, :L]},
                                    max_seq=L + 1)
    logits_d, _ = model.decode_step(params, toks[:, L], cache,
                                    jnp.asarray(L, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(full_logits),
                               atol=2e-3, rtol=2e-3)


def test_encdec_prefill_decode_consistency():
    cfg = configs.get_smoke("seamless-m4t-medium")
    model = build(cfg, RC)
    params, _ = model.init(jax.random.PRNGKey(0))
    L = 10
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (2, L + 1), 0, cfg.vocab, jnp.int32)
    frames = jax.random.normal(key, (2, cfg.source_len, cfg.d_model)) * 0.02
    full, _ = model.prefill(params, {"tokens": toks, "frames": frames},
                            max_seq=L + 1)
    part, cache = model.prefill(params, {"tokens": toks[:, :L],
                                         "frames": frames}, max_seq=L + 1)
    dec, _ = model.decode_step(params, toks[:, L], cache,
                               jnp.asarray(L, jnp.int32))
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-3,
                               rtol=2e-3)


def test_vlm_prefix_shifts_loss():
    cfg = configs.get_smoke("phi-3-vision-4.2b")
    model = build(cfg, RC)
    params, _ = model.init(jax.random.PRNGKey(0))
    b = synth_batch(model, jax.random.PRNGKey(1), 32, 2, "train")
    assert "patch_embeds" in b
    loss = model.loss_fn(params, b)
    b2 = dict(b, patch_embeds=b["patch_embeds"] * 0 + 1.0)
    loss2 = model.loss_fn(params, b2)
    assert float(loss) != float(loss2)  # the stub frontend is actually used


def test_param_counts_sane():
    """Analytic param counts are within 25% of actual initialized counts
    for the reduced configs (sanity for MODEL_FLOPS in the roofline)."""
    for arch in configs.ARCH_IDS:
        cfg = configs.get_smoke(arch)
        model = build(cfg, RC)
        params, _ = model.init(jax.random.PRNGKey(0))
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert 0.5 < analytic / actual < 1.6, (arch, analytic, actual)


def test_window_attention_limits_context():
    """With ONE layer and window w, a token farther than w behind the last
    position cannot influence the last logits AT ALL (strict SWA check)."""
    import dataclasses
    cfg = dataclasses.replace(configs.get_smoke("stablelm-3b"), n_layers=1,
                              window=8)
    model = build(cfg, RC)
    params, _ = model.init(jax.random.PRNGKey(0))
    L = 24
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, L), 0, cfg.vocab,
                              jnp.int32)
    out1, _ = model.prefill(params, {"tokens": toks}, max_seq=L)
    toks_far = toks.at[0, 2].set((toks[0, 2] + 1) % cfg.vocab)  # L-1-2 > 8
    out2, _ = model.prefill(params, {"tokens": toks_far}, max_seq=L)
    toks_near = toks.at[0, L - 2].set((toks[0, L - 2] + 1) % cfg.vocab)
    out3, _ = model.prefill(params, {"tokens": toks_near}, max_seq=L)
    far = float(jnp.max(jnp.abs(out2 - out1)))
    near = float(jnp.max(jnp.abs(out3 - out1)))
    assert far == 0.0, far
    assert near > 0.0, near
