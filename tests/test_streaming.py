"""Streaming (marching-axis) execution: `march_axis=` slides one grid
axis sequentially, reusing VMEM plane queues instead of refetching halo
windows. Streamed results must equal the all-parallel path — bitwise
within one compiled program, 1-ulp (`allclose(atol≈1e-6)`) across
separately compiled programs — for plain, coupled/staggered,
asymmetric-halo and temporally-blocked kernels on both backends, with a
graceful fallback when the march extent cannot fill the plane queue and
pointed errors for unsupported geometries."""
import numpy as np
import jax.numpy as jnp
import pytest

from conftest import run_subprocess
from repro.core import fd2d, fd3d, init_parallel_stencil, teff
from repro.kernels import autotune
from repro.launch import roofline as _roofline

SHAPE3 = (20, 16, 24)
SC3 = dict(lam=1.0, dt=1e-4, _dx=float(SHAPE3[0] - 1),
           _dy=float(SHAPE3[1] - 1), _dz=float(SHAPE3[2] - 1))


def _diffusion(backend, march=None, tile=None):
    ps = init_parallel_stencil(backend=backend, ndims=3)

    @ps.parallel(outputs=("T2",), rotations={"T2": "T"}, march_axis=march,
                 tile=tile)
    def kern(T2, T, Ci, lam, dt, _dx, _dy, _dz):
        return {"T2": fd3d.inn(T) + dt * (lam * fd3d.inn(Ci) * (
            fd3d.d2_xi(T) * _dx ** 2 + fd3d.d2_yi(T) * _dy ** 2 +
            fd3d.d2_zi(T) * _dz ** 2))}
    return kern


def _fields3(rng):
    T = jnp.asarray(rng.rand(*SHAPE3), jnp.float32)
    return T.copy(), T, jnp.asarray(rng.rand(*SHAPE3) + 0.5, jnp.float32)


# ---------------------------------------------------------------------------
# plain kernel: streamed == all-parallel on every axis, both backends
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("march", [0, 1, 2])
def test_streamed_matches_parallel(backend, march, rng):
    T2, T, Ci = _fields3(rng)
    want = np.asarray(_diffusion("jnp")(T2=T2, T=T, Ci=Ci, **SC3))
    k = _diffusion(backend, march=march, tile=(4, 4, 8))
    got = np.asarray(k(T2=T2, T=T, Ci=Ci, **SC3))
    np.testing.assert_allclose(got, want, atol=1e-6)
    if backend == "pallas":
        run = next(iter(k._cache.values()))
        assert run.march_axis == march and not run.march_fallback
        assert run.queue_planes > 0


# ---------------------------------------------------------------------------
# temporal blocking: streamed k-step == all-parallel k-step, and the
# streamed kernel is self-consistent (fused vs sequential, same object)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("k", [1, 2, 4])
def test_streamed_temporal_matches_parallel(backend, k, rng):
    T2, T, Ci = _fields3(rng)
    want = np.asarray(_diffusion(backend).run_steps(k, T2=T2, T=T, Ci=Ci,
                                                    **SC3))
    kern = _diffusion(backend, march=0, tile=(4, 4, 8))
    got = np.asarray(kern.run_steps(k, T2=T2, T=T, Ci=Ci, **SC3))
    np.testing.assert_allclose(got, want, atol=1e-6)
    if backend == "pallas" and k > 1:
        run = [v for kk, v in kern._cache.items() if kk[3] == k][0]
        assert run.march_axis == 0 and not run.march_fallback


@pytest.mark.parametrize("k", [2, 4])
def test_streamed_run_steps_matches_own_sequential(k, rng):
    """The fused streamed k-step launch equals k sequential rotated calls
    of the same kernel object to 1 ulp (the fused program's shrinking
    sweep margins compile to different FMA contractions than the
    single-step windows, so this is a cross-program comparison — the
    engine's bitwise guarantee only holds within one compiled program)."""
    T2, T, Ci = _fields3(rng)
    kern = _diffusion("pallas", march=0, tile=(4, 4, 8))
    a, b = T2, T
    for _ in range(k):
        a = kern(T2=a, T=b, Ci=Ci, **SC3)
        a, b = b, a
    got = np.asarray(kern.run_steps(k, T2=T2, T=T, Ci=Ci, **SC3))
    np.testing.assert_allclose(got, np.asarray(b), atol=1e-6)
    # determinism within one compiled program: re-running the fused
    # launch on the same inputs is bitwise
    again = np.asarray(kern.run_steps(k, T2=T2, T=T, Ci=Ci, **SC3))
    np.testing.assert_array_equal(got, again)


# ---------------------------------------------------------------------------
# coupled / staggered systems
# ---------------------------------------------------------------------------
def _coupled2d(backend, march=None, tile=None):
    """phi2/Pe2 coupled outputs + a face-centered flux INPUT staggered
    along axis 0 (so march_axis=1 is the streamable one)."""
    ps = init_parallel_stencil(backend=backend, ndims=2)

    @ps.parallel(outputs=("phi2", "Pe2"), march_axis=march, tile=tile,
                 rotations={"phi2": "phi", "Pe2": "Pe"})
    def kern(phi2, Pe2, phi, Pe, qx, dtau):
        div = qx[1:, 1:-1] - qx[:-1, 1:-1]
        return {
            "phi2": fd2d.inn(phi) + dtau * (fd2d.d2_xi(phi) + fd2d.d2_yi(phi)
                                            - div),
            "Pe2": fd2d.inn(Pe) + dtau * (fd2d.d2_xi(Pe) + fd2d.d2_yi(Pe)
                                          + fd2d.inn(phi)),
        }
    return kern


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("k", [1, 2])
def test_streamed_coupled_staggered(backend, k, rng):
    n = 24
    phi = jnp.asarray(rng.rand(n, n), jnp.float32)
    Pe = jnp.asarray(rng.rand(n, n), jnp.float32)
    qx = jnp.asarray(rng.rand(n - 1, n), jnp.float32)
    args = dict(phi2=phi, Pe2=Pe, phi=phi, Pe=Pe, qx=qx, dtau=1e-3)
    want = _coupled2d("jnp").run_steps(k, **args)
    kern = _coupled2d(backend, march=1, tile=(4, 4))
    got = kern.run_steps(k, **args)
    for o in ("phi2", "Pe2"):
        np.testing.assert_allclose(np.asarray(got[o]), np.asarray(want[o]),
                                   atol=1e-6)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_streamed_staggered_march_axis_raises(backend, rng):
    n = 24
    phi = jnp.asarray(rng.rand(n, n), jnp.float32)
    Pe = jnp.asarray(rng.rand(n, n), jnp.float32)
    qx = jnp.asarray(rng.rand(n - 1, n), jnp.float32)
    kern = _coupled2d(backend, march=0, tile=(4, 4))
    with pytest.raises(ValueError, match="staggered"):
        kern(phi2=phi, Pe2=Pe, phi=phi, Pe=Pe, qx=qx, dtau=1e-3)


def test_march_axis_out_of_range():
    ps = init_parallel_stencil(backend="jnp", ndims=2)
    with pytest.raises(ValueError, match="out of range"):
        ps.parallel(outputs=("T2",), march_axis=2)


# ---------------------------------------------------------------------------
# asymmetric (upwind) footprints
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("march", [0, 1])
def test_streamed_upwind_asymmetric(backend, march, rng):
    def upwind(T2, T, dt):
        return {"T2": fd2d.inn(T) + dt * (T[:-2, 1:-1] - T[1:-1, 1:-1])}

    U = jnp.asarray(rng.rand(20, 24), jnp.float32)
    ps = init_parallel_stencil(backend="jnp", ndims=2)
    want = np.asarray(ps.parallel(outputs=("T2",))(upwind)(T2=U, T=U, dt=1e-3))
    ps = init_parallel_stencil(backend=backend, ndims=2)
    k = ps.parallel(outputs=("T2",), march_axis=march, tile=(4, 4))(upwind)
    got = np.asarray(k(T2=U, T=U, dt=1e-3))
    np.testing.assert_allclose(got, want, atol=1e-6)


# ---------------------------------------------------------------------------
# fallback: march extent smaller than the plane queue
# ---------------------------------------------------------------------------
def test_streamed_fallback_small_march_extent(rng):
    T2, T, Ci = _fields3(rng)
    # k=4 sweeps need a 3-block (30-plane) queue at tile 10 > 20 planes
    kern = _diffusion("pallas", march=0, tile=(10, 4, 8))
    want = np.asarray(_diffusion("pallas", tile=(10, 4, 8)).run_steps(
        4, T2=T2, T=T, Ci=Ci, **SC3))
    got = np.asarray(kern.run_steps(4, T2=T2, T=T, Ci=Ci, **SC3))
    np.testing.assert_array_equal(got, want)
    run = [v for kk, v in kern._cache.items() if kk[3] == 4][0]
    assert run.march_axis is None and run.march_fallback


def test_jnp_march_fallback_tiny_axis(rng):
    """A march extent smaller than one slab degenerates to the plain jnp
    realization (identical semantics, no crash)."""
    U = jnp.asarray(rng.rand(3, 24), jnp.float32)
    ps = init_parallel_stencil(backend="jnp", ndims=2)

    def lap(T2, T, dt):
        return {"T2": fd2d.inn(T) + dt * (fd2d.d2_xi(T) + fd2d.d2_yi(T))}

    want = np.asarray(ps.parallel(outputs=("T2",))(lap)(T2=U, T=U, dt=1e-3))
    got = np.asarray(ps.parallel(outputs=("T2",), march_axis=0)(lap)(
        T2=U, T=U, dt=1e-3))
    np.testing.assert_allclose(got, want, atol=1e-6)


# ---------------------------------------------------------------------------
# marched() variants and the overlapped interior
# ---------------------------------------------------------------------------
def test_marched_variant_memoized(rng):
    kern = _diffusion("pallas", tile=(4, 4, 8))
    assert kern.marched(None) is kern
    m0 = kern.marched(0)
    assert m0 is kern.marched(0)
    assert m0.march_axis == 0 and kern.march_axis is None


def test_overlapped_step_streamed_interior():
    """@hide_communication with a streamed bulk update: the overlapped
    result equals the sequential exchange-then-update reference (shell
    slabs stay all-parallel; only the interior launch marches)."""
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.core import init_parallel_stencil, fd2d
from repro.distributed import halo, overlap
from repro.launch.mesh import make_mesh

mesh = make_mesh((4,), ("x",))
ps = init_parallel_stencil(backend="jnp", ndims=2)

@ps.parallel(outputs=("U2",))
def kern(U2, U, dt):
    return {"U2": fd2d.inn(U) + dt * (fd2d.d2_xi(U) + fd2d.d2_yi(U))}

rng = np.random.RandomState(0)
Ng = 4 * 16 + 2
Ug = jnp.asarray(rng.rand(Ng, 20), jnp.float32)

locs = halo.global_to_local(Ug, (4,), radius=1)
Us = jnp.asarray(np.stack(locs))
sc = dict(dt=1e-3)

def step(Ul):
    Ul = Ul[0]
    fields = dict(U2=Ul, U=Ul)
    seq, _ = overlap.sequential_step(kern, fields, sc, ("U",), ("x",))
    ovl, _ = overlap.overlapped_step(kern, fields, sc, ("U",), ("x",),
                                     march_axis=0)
    return seq[None], ovl[None]

f = shard_map(step, mesh=mesh, in_specs=P("x"), out_specs=(P("x"), P("x")),
              check_vma=False)
seq, ovl = f(Us)
d = float(np.max(np.abs(np.asarray(seq) - np.asarray(ovl))))
assert d < 1e-6, d
print("MARCH_OVERLAP_OK", d)
""", n_devices=4)
    assert "MARCH_OVERLAP_OK" in out


# ---------------------------------------------------------------------------
# analytic streamed-bytes model + autotune integration
# ---------------------------------------------------------------------------
def test_streamed_bytes_model_drops_march_overlap(rng):
    kern = _diffusion("jnp")
    cost = kern.cost_model(T2=SHAPE3, T=SHAPE3, Ci=SHAPE3, **SC3)
    tile = (4, 4, 8)
    refetched = cost.fetched_bytes_per_step(tile, 2)
    streamed = cost.a_eff_streamed(tile, 2, march_axis=0)
    assert streamed < refetched
    # the streamed model still exceeds the ideal once-per-sweep traffic
    assert streamed > cost.a_eff_bytes(2)
    # the teff-level factors tell the same story
    full = teff.window_overlap_factor(tile, cost.halo, 2)
    rest = teff.window_overlap_factor(tile, cost.halo, 2, march_axis=0)
    assert rest < full
    n = int(np.prod(SHAPE3))
    assert teff.a_eff_streamed(n, 2, 1, 4, nsteps=2, overlap=rest) < \
        teff.a_eff_streamed(n, 2, 1, 4, nsteps=2, overlap=full)


def test_roofline_records_streamed_traffic(rng):
    kern = _diffusion("jnp")
    cost = kern.cost_model(T2=SHAPE3, T=SHAPE3, Ci=SHAPE3, **SC3)
    rec = _roofline.stencil_roofline(cost, nsteps=2, tile=(4, 4, 8),
                                     march_axis=0)
    assert rec["streamed_bytes_per_step"] < rec["refetched_bytes_per_step"]
    assert rec["march_axis"] == 0


def test_autotune_march_candidates_and_cache_version(tmp_path, rng):
    path = str(tmp_path / "tune.json")
    # an old-format (pre-versioned) cache file must be ignored, not
    # crashed on — and gets rewritten in the new format
    import json
    with open(path, "w") as f:
        json.dump({"[\"old\"]": {"tile": [8, 8, 8], "nsteps": 1,
                                 "per_step_s": 1e-9}}, f)
    assert autotune._load_cache(path) == {}
    autotune._CACHE.clear()
    r = autotune.autotune_diffusion3d(
        (16, 16, 16), nsteps_candidates=(1, 2), iters=1, cache_path=path,
        march_candidates=(None, 0))
    assert r.march_axis in (None, 0)
    assert r.candidates_tried >= 1
    with open(path) as f:
        disk = json.load(f)
    assert disk["version"] == autotune.CACHE_VERSION
    # the winner round-trips through the versioned cache
    autotune._CACHE.clear()
    r2 = autotune.autotune_diffusion3d(
        (16, 16, 16), nsteps_candidates=(1, 2), iters=1, cache_path=path,
        march_candidates=(None, 0))
    assert r2 == r


def test_autotune_march_prunes_with_cost_model(rng):
    autotune._CACHE.clear()
    r = autotune.autotune_diffusion3d(
        (16, 16, 16), nsteps_candidates=(1, 2), iters=1,
        hw=teff.TPU_V5E, prune_ratio=1.05,
        march_candidates=(None, 0))
    # the analytic model ranks (tile, k, march) candidates; with a tight
    # ratio at least one config must have been dropped pre-compile
    assert r.candidates_pruned >= 1


def test_autotune_march_distinct_cache_keys():
    k1 = autotune.cache_key((8, 8), "float32", 1, 3, "t", (1,))
    k2 = autotune.cache_key((8, 8), "float32", 1, 3, "t", (1,),
                            march_candidates=(None, 0))
    k3 = autotune.cache_key((8, 8), "float32", 1, 3, "t", (1,),
                            halos=((1, 0), (0, 0)))
    assert len({k1, k2, k3}) == 3
