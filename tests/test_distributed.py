"""Multi-device tests (subprocess with fake CPU devices): halo exchange,
comm/compute overlap (paper C6), flash-decoding, compression, elastic."""
import numpy as np
import pytest

from conftest import run_subprocess


def test_halo_overlap_and_multistep():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.core import init_parallel_stencil, fd3d as fd
from repro.distributed import halo, overlap
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 2), ("x", "y"))
Ng, Nz = 34, 10
rng = np.random.RandomState(0)
Tg = jnp.asarray(rng.rand(Ng, Ng, Nz), jnp.float32)
Cig = jnp.asarray(rng.rand(Ng, Ng, Nz) + 0.5, jnp.float32)
sc = dict(lam=1.0, dt=1e-4, _dx=1.0, _dy=1.0, _dz=1.0)

ps = init_parallel_stencil(backend="jnp", ndims=3)
@ps.parallel(outputs=("T2",))
def kern(T2, T, Ci, lam, dt, _dx, _dy, _dz):
    return {"T2": fd.inn(T) + dt*(lam*fd.inn(Ci)*(fd.d2_xi(T)*_dx**2
            + fd.d2_yi(T)*_dy**2 + fd.d2_zi(T)*_dz**2))}

# single-device reference: 3 steps
Tr = Tg
for _ in range(3):
    Tr = kern(T2=Tr, T=Tr, Ci=Cig, **sc)

lT = halo.global_to_local(Tg, (2, 2)); lC = halo.global_to_local(Cig, (2, 2))
ls = lT[0].shape
Ts = jnp.asarray(np.stack(lT).reshape(2, 2, *ls))
Cs = jnp.asarray(np.stack(lC).reshape(2, 2, *ls))

def steps(Tl, Cl):
    Tl, Cl = Tl[0, 0], Cl[0, 0]
    for _ in range(3):
        fields = dict(T2=Tl, T=Tl, Ci=Cl)
        Tl, fresh = overlap.overlapped_step(kern, fields, sc, ("T",), ("x", "y"))
    return Tl[None, None]

f = shard_map(steps, mesh=mesh, in_specs=(P("x","y"), P("x","y")),
              out_specs=P("x","y"), check_vma=False)
got = halo.local_to_global(list(np.asarray(f(Ts, Cs)).reshape(4, *ls)), (2, 2))
err = float(np.max(np.abs(got - np.asarray(Tr))))
print("MULTISTEP_ERR", err)
assert err < 1e-6
""")
    assert "MULTISTEP_ERR" in out


def test_deep_halo_temporal_blocking():
    """One radius=k*r exchange + k fused local steps must reproduce the
    single-device k-step solution on the owned interiors (the distributed
    face of temporal blocking: k x fewer messages)."""
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import init_parallel_stencil, fd3d as fd
from repro.distributed import halo, overlap
from repro.launch.mesh import make_mesh

K = 3  # temporal block depth; ghost width = K * radius
mesh = make_mesh((2, 2), ("x", "y"))
Ni, Nz = 24, 10
Ng = Ni + 2 * K  # global array with K-wide physical boundary ring
rng = np.random.RandomState(0)
Tg = jnp.asarray(rng.rand(Ng, Ng, Nz), jnp.float32)
Cig = jnp.asarray(rng.rand(Ng, Ng, Nz) + 0.5, jnp.float32)
sc = dict(lam=1.0, dt=1e-4, _dx=1.0, _dy=1.0, _dz=1.0)

ps = init_parallel_stencil(backend="jnp", ndims=3)
@ps.parallel(outputs=("T2",), rotations={"T2": "T"})
def kern(T2, T, Ci, lam, dt, _dx, _dy, _dz):
    return {"T2": fd.inn(T) + dt*(lam*fd.inn(Ci)*(fd.d2_xi(T)*_dx**2
            + fd.d2_yi(T)*_dy**2 + fd.d2_zi(T)*_dz**2))}

# single-device reference: K rotated steps
a, b = Tg, Tg
for _ in range(K):
    a = kern(T2=a, T=b, Ci=Cig, **sc)
    a, b = b, a
Tr = b

lT = halo.global_to_local(Tg, (2, 2), radius=K)
lC = halo.global_to_local(Cig, (2, 2), radius=K)
ls = lT[0].shape
Ts = jnp.asarray(np.stack(lT).reshape(2, 2, *ls))
Cs = jnp.asarray(np.stack(lC).reshape(2, 2, *ls))

def steps(Tl, Cl):
    Tl, Cl = Tl[0, 0], Cl[0, 0]
    fields = dict(T2=Tl, T=Tl, Ci=Cl)
    out, _ = overlap.multi_step(kern, fields, sc, ("T",), ("x", "y"), K)
    return out[None, None]

f = shard_map(steps, mesh=mesh, in_specs=(P("x","y"), P("x","y")),
              out_specs=P("x","y"), check_vma=False)
got = halo.local_to_global(list(np.asarray(f(Ts, Cs)).reshape(4, *ls)),
                           (2, 2), radius=K)
# owned interiors (depth >= K from the global ring) must match exactly
err = float(np.max(np.abs(np.asarray(got)[K:-K, K:-K]
                          - np.asarray(Tr)[K:-K, K:-K])))
print("DEEP_HALO_ERR", err)
assert err < 1e-6
""")
    assert "DEEP_HALO_ERR" in out


def test_periodic_halo_wraps():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.distributed import halo
from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("x",))
n_local = 6
full = jnp.arange(4 * (n_local - 2), dtype=jnp.float32) + 100
locs = [jnp.pad(full[i*(n_local-2):(i+1)*(n_local-2)], (1, 1)) for i in range(4)]
arr = jnp.stack(locs)
def fn(a):
    return halo.halo_exchange(a[0], ("x",), radius=1, periodic=True)[None]
f = shard_map(fn, mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_vma=False)
out = np.asarray(f(arr))
# rank 0 low ghost must equal the LAST interior value (wrap)
assert out[0, 0] == float(full[-1]), (out[0, 0], float(full[-1]))
assert out[3, -1] == float(full[0])
print("PERIODIC_OK")
""")
    assert "PERIODIC_OK" in out


def test_seq_sharded_decode_attention():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed import sharding
from repro.kernels import ops
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
rng = np.random.RandomState(0)
B, Hq, Hkv, S, D = 4, 8, 2, 64, 16
q = jnp.asarray(rng.randn(B, Hq, D), jnp.float32)
kc = jnp.asarray(rng.randn(B, Hkv, S, D), jnp.float32)
vc = jnp.asarray(rng.randn(B, Hkv, S, D), jnp.float32)
for pos, win in [(40, None), (40, 16), (None, None)]:
    want = ops.decode_attention(q, kc, vc, pos=None if pos is None else jnp.asarray(pos), window=win)
    got = sharding.seq_sharded_decode_attention(
        q, kc, vc, mesh=mesh, seq_axes=("model",), batch_axes=("data",),
        pos=pos, window=win)
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 1e-5, (pos, win, err)
print("FLASH_DECODE_OK")
""")
    assert "FLASH_DECODE_OK" in out


def test_compressed_psum_and_error_feedback():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.distributed import compression
from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("pod",))
rng = np.random.RandomState(1)
g = jnp.asarray(rng.randn(4, 1000), jnp.float32)
def f(gl, err):
    red, new_err = compression.compressed_psum(gl[0], "pod", err[0])
    return red[None], new_err[None]
fn = shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
               out_specs=(P("pod"), P("pod")), check_vma=False)
exact = jnp.sum(g, 0)
err = jnp.zeros_like(g)
red, err = fn(g, err)
rel = float(jnp.max(jnp.abs(red[0] - exact)) / jnp.max(jnp.abs(exact)))
assert rel < 0.05, rel
# error feedback: residual is carried, bias shrinks over repeats
accum = jnp.zeros_like(exact)
err = jnp.zeros_like(g)
for _ in range(50):
    red, err = fn(g, err)
    accum = accum + red[0]
bias = float(jnp.max(jnp.abs(accum / 50 - exact)))
assert bias < 0.02 * float(jnp.max(jnp.abs(exact))), bias
print("COMPRESS_OK", rel)
""")
    assert "COMPRESS_OK" in out


def test_elastic_restore_across_meshes():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager
from repro.launch.mesh import make_mesh
tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
        "b": jnp.arange(8, dtype=jnp.float32)}
with tempfile.TemporaryDirectory() as td:
    m1 = make_mesh((4, 2), ("data", "model"))
    t1 = jax.tree.map(lambda x: jax.device_put(
        x, NamedSharding(m1, P("data") if x.ndim == 1 else P("data", "model"))), tree)
    mgr = CheckpointManager(td)
    mgr.save(1, t1)
    # restore on a DIFFERENT mesh shape
    m2 = make_mesh((2, 4), ("data", "model"))
    sh2 = jax.tree.map(lambda x: NamedSharding(
        m2, P("model") if x.ndim == 1 else P("model", "data")), tree)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored, extra = mgr.restore(like, shardings=sh2)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(restored[k]), np.asarray(tree[k]))
        assert restored[k].sharding.mesh.shape == m2.shape
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out


def test_global_local_roundtrip(rng):
    """global_to_local / local_to_global are exact inverses (any radius)."""
    import jax.numpy as jnp
    from repro.distributed import halo
    for radius, factors in [(1, (2, 2)), (2, (2, 4)), (1, (4, 1))]:
        inner = (8 * factors[0], 8 * factors[1])
        g = rng.rand(inner[0] + 2 * radius, inner[1] + 2 * radius, 5)
        locs = halo.global_to_local(jnp.asarray(g, jnp.float32), factors,
                                    radius=radius)
        back = halo.local_to_global(locs, factors, radius=radius)
        np.testing.assert_array_equal(back, np.float32(g))


def test_halo_radius2_overlap():
    """Radius-2 stencils (4th-order FD) exchange 2-wide halos and overlap
    bitwise like radius-1."""
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.core import init_parallel_stencil
from repro.distributed import halo, overlap
from repro.launch.mesh import make_mesh

mesh = make_mesh((4,), ("x",))
ps = init_parallel_stencil(backend="jnp", ndims=2)

@ps.parallel(outputs=("U2",), radius=2)
def kern(U2, U, dt):
    # 4th-order laplacian in x (radius 2), 2nd order in y
    d4 = (-U[4:, 2:-2] + 16*U[3:-1, 2:-2] - 30*U[2:-2, 2:-2]
          + 16*U[1:-3, 2:-2] - U[:-4, 2:-2]) / 12.0
    d2 = U[2:-2, 3:-1] - 2*U[2:-2, 2:-2] + U[2:-2, 1:-3]
    return {"U2": U[2:-2, 2:-2] + dt * (d4 + d2)}

rng = np.random.RandomState(0)
Ng = 4 * 16 + 4   # interior 64, radius 2
Ug = jnp.asarray(rng.rand(Ng, 20), jnp.float32)
want = kern(U2=Ug, U=Ug, dt=1e-3)

locs = halo.global_to_local(Ug, (4,), radius=2)
ls = locs[0].shape
Us = jnp.asarray(np.stack(locs))
sc = dict(dt=1e-3)

def step(Ul):
    Ul = Ul[0]
    fields = dict(U2=Ul, U=Ul)
    seq, _ = overlap.sequential_step(kern, fields, sc, ("U",), ("x",))
    ovl, _ = overlap.overlapped_step(kern, fields, sc, ("U",), ("x",))
    return seq[None], ovl[None]

f = shard_map(step, mesh=mesh, in_specs=P("x"), out_specs=(P("x"), P("x")),
              check_vma=False)
seq, ovl = f(Us)
assert (np.asarray(seq) == np.asarray(ovl)).all(), "overlap != sequential"
got = halo.local_to_global(list(np.asarray(seq)), (4,), radius=2)
err = float(np.max(np.abs(got - np.asarray(want))))
assert err < 1e-6, err
print("RADIUS2_OK", err)
""")
    assert "RADIUS2_OK" in out


def test_grouped_exchange_matches_per_field():
    """One-message-per-direction grouped halo exchange must be value-
    identical to per-field exchanges, for float and int fields, periodic
    and not, and a coupled multi-output kernel must step correctly on
    grouped-fresh fields."""
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.distributed import halo
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 2), ("x", "y"))
rng = np.random.RandomState(0)
ls = (12, 14, 6)
A = jnp.asarray(rng.rand(2, 2, *ls), jnp.float32)
B = jnp.asarray(rng.rand(2, 2, *ls), jnp.float32)
C = jnp.asarray(rng.randint(0, 100, (2, 2, *ls)), jnp.int32)

def f(A, B, C):
    fields = dict(A=A[0, 0], B=B[0, 0], C=C[0, 0])
    diffs = []
    for per in (False, True):
        g = halo.exchange_many(fields, ("A", "B", "C"), ("x", "y"),
                               radius=2, periodic=per, grouped=True)
        s = halo.exchange_many(fields, ("A", "B", "C"), ("x", "y"),
                               radius=2, periodic=per, grouped=False)
        for n in ("A", "B", "C"):
            diffs.append(jnp.max(jnp.abs((g[n] - s[n]).astype(jnp.float32))))
    return jnp.stack(diffs).max()[None, None]

g = shard_map(f, mesh=mesh, in_specs=(P("x","y"), P("x","y"), P("x","y")),
              out_specs=P("x","y"), check_vma=False)
d = float(np.max(np.asarray(g(A, B, C))))
assert d == 0.0, d
print("GROUPED_OK", d)
""")
    assert "GROUPED_OK" in out


def test_overlapped_step_coupled_staggered_inputs():
    """Coupled multi-output kernel with face-centered INPUT fields under
    @hide_communication: overlapped == sequential exchange-then-update,
    and the offset-aware face slabs keep the staggering contract."""
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import init_parallel_stencil, fd2d as fd
from repro.distributed import overlap
from repro.launch.mesh import make_mesh

mesh = make_mesh((4,), ("x",))
rng = np.random.RandomState(0)
ls = (18, 16)   # local (with ghosts); qx staggered along decomposed axis x
phi = jnp.asarray(rng.rand(4, *ls), jnp.float32)
Pe = jnp.asarray(rng.rand(4, *ls), jnp.float32)
qx = jnp.asarray(rng.rand(4, ls[0] - 1, ls[1]), jnp.float32)
qy = jnp.asarray(rng.rand(4, ls[0], ls[1] - 1), jnp.float32)

ps = init_parallel_stencil(backend="jnp", ndims=2)
@ps.parallel(outputs=("phi2", "Pe2"))
def kern(phi2, Pe2, phi, Pe, qx, qy, dtau):
    div_q = fd.d_xa(qx[:, 1:-1]) + fd.d_ya(qy[1:-1, :])
    Pe_new = fd.inn(Pe) + dtau * (-(div_q + fd.inn(Pe)))
    phi_new = fd.inn(phi) + dtau * (-(1.0 - fd.inn(phi)) * Pe_new)
    return {"phi2": phi_new, "Pe2": Pe_new}

sc = dict(dtau=0.01)

def f(phi, Pe, qx, qy):
    fields = dict(phi2=phi[0], Pe2=Pe[0], phi=phi[0], Pe=Pe[0],
                  qx=qx[0], qy=qy[0])
    seq, _ = overlap.sequential_step(kern, fields, sc, ("phi", "Pe"), ("x",))
    ovl, _ = overlap.overlapped_step(kern, fields, sc, ("phi", "Pe"), ("x",))
    d = jnp.maximum(jnp.max(jnp.abs(seq["phi2"] - ovl["phi2"])),
                    jnp.max(jnp.abs(seq["Pe2"] - ovl["Pe2"])))
    return d[None]

g = shard_map(f, mesh=mesh, in_specs=(P("x"),) * 4, out_specs=P("x"),
              check_vma=False)
d = float(np.max(np.asarray(g(phi, Pe, qx, qy))))
assert d == 0.0, d
print("COUPLED_OVERLAP_OK", d)
""")
    assert "COUPLED_OVERLAP_OK" in out


def test_exchange_depths_tighten_traffic():
    """Footprint-tightened exchange: refreshing only each field's
    inferred per-axis/per-side read depth yields kernel results identical
    to the full-radius exchange on the owned cells (the unread outer
    ghost layers may stay stale — the stencil never touches them)."""
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import init_parallel_stencil
from repro.distributed import halo, overlap
from repro.launch.mesh import make_mesh

mesh = make_mesh((4,), ("x",))
rng = np.random.RandomState(0)
R = 2                        # allocated ghost width
ls = (16 + 2 * R, 12)
U = jnp.asarray(rng.rand(4, *ls), jnp.float32)

ps = init_parallel_stencil(backend="jnp", ndims=2)
@ps.parallel(outputs=("U2",))
def kern(U2, U, dt):
    # one-sided in x: reads only U[i-2..i] -> depth (2, 0) on x
    return {"U2": U[2:-2, 1:-1] + dt * (U[:-4, 1:-1] - U[2:-2, 1:-1])}

ir = kern.stencil_ir(U2=ls, U=ls, dt=0.0)
assert ir.field_halo["U"] == ((2, 0), (0, 0)), ir.field_halo
sc = dict(dt=1e-3)

def f(Ul):
    Ul = Ul[0]
    full = halo.exchange_many(dict(U=Ul), ("U",), ("x",), radius=R)
    tight = halo.exchange_many(dict(U=Ul), ("U",), ("x",), radius=R,
                               depths={"U": ir.field_halo["U"][:1]})
    a = kern(U2=full["U"], U=full["U"], **sc)
    b = kern(U2=tight["U"], U=tight["U"], **sc)
    # owned cells (inside the ghost ring) must agree exactly
    d = jnp.max(jnp.abs(a[R:-R] - b[R:-R]))
    # sequential_step picks the tightened depths up automatically
    seq_full, _ = overlap.sequential_step(kern, dict(U2=Ul, U=Ul), sc,
                                          ("U",), ("x",))
    d2 = jnp.max(jnp.abs(a[R:-R] - seq_full[R:-R]))
    return jnp.maximum(d, d2)[None]

g = shard_map(f, mesh=mesh, in_specs=(P("x"),), out_specs=P("x"),
              check_vma=False)
d = float(np.max(np.asarray(g(U))))
assert d == 0.0, d
print("DEPTHS_OK", d)
""")
    assert "DEPTHS_OK" in out
