"""Temporal blocking (nsteps=k): the k-step fused path must be
bitwise-consistent with k sequential single-step calls (double-buffer
rotation) on the jnp and pallas-interpret backends, for the generic
StencilKernel and the hand-specialized diffusion3d kernel, plus the
autotuner and the blocked T_eff accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fd2d, fd3d, init_parallel_stencil, teff
from repro.kernels import autotune, diffusion3d, ref

SHAPE = (20, 16, 24)
SC = dict(lam=1.0, dt=1e-4, _dx=float(SHAPE[0] - 1), _dy=float(SHAPE[1] - 1),
          _dz=float(SHAPE[2] - 1))


def _diffusion_kernel(ps):
    @ps.parallel(outputs=("T2",), rotations={"T2": "T"})
    def kern(T2, T, Ci, lam, dt, _dx, _dy, _dz):
        return {"T2": fd3d.inn(T) + dt * (lam * fd3d.inn(Ci) * (
            fd3d.d2_xi(T) * _dx ** 2 + fd3d.d2_yi(T) * _dy ** 2 +
            fd3d.d2_zi(T) * _dz ** 2))}
    return kern


def _fields(rng):
    T = jnp.asarray(rng.rand(*SHAPE), jnp.float32)
    return T.copy(), T, jnp.asarray(rng.rand(*SHAPE) + 0.5, jnp.float32)


def _sequential(kern, T2, T, Ci, k):
    a, b = T2, T
    for _ in range(k):
        a = kern(T2=a, T=b, Ci=Ci, **SC)
        a, b = b, a
    return np.asarray(b)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("k", [1, 2, 4])
def test_run_steps_bitwise_matches_sequential(backend, k, rng):
    T2, T, Ci = _fields(rng)
    kern = _diffusion_kernel(init_parallel_stencil(backend=backend, ndims=3))
    want = _sequential(kern, T2, T, Ci, k)
    got = np.asarray(kern.run_steps(k, T2=T2, T=T, Ci=Ci, **SC))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("k", [2, 4])
def test_run_steps_backends_agree(k, rng):
    T2, T, Ci = _fields(rng)
    outs = {}
    for backend in ("jnp", "pallas"):
        kern = _diffusion_kernel(init_parallel_stencil(backend=backend, ndims=3))
        outs[backend] = np.asarray(kern.run_steps(k, T2=T2, T=T, Ci=Ci, **SC))
    np.testing.assert_allclose(outs["jnp"], outs["pallas"], atol=5e-6)


@pytest.mark.parametrize("k", [1, 2, 4])
def test_hand_diffusion3d_nsteps_bitwise(k, rng):
    T2, T, Ci = _fields(rng)
    args = (1.0, 1e-4, SC["_dx"], SC["_dy"], SC["_dz"])
    a, b = T2, T
    for _ in range(k):
        a = diffusion3d.diffusion3d_step(a, b, Ci, *args)
        a, b = b, a
    want = np.asarray(b)
    got = np.asarray(diffusion3d.diffusion3d_step(T2, T, Ci, *args, nsteps=k))
    np.testing.assert_array_equal(got, want)
    # and the fused result still tracks the jnp oracle chain
    a, b = T2, T
    for _ in range(k):
        a = ref.diffusion3d_step(a, b, Ci, *args)
        a, b = b, a
    np.testing.assert_allclose(got, np.asarray(b), atol=5e-6)


def test_nsteps_boundary_preserved(rng):
    """k-step fused launches keep the write buffer's boundary ring, exactly
    like a single step (the paper's @inn semantics)."""
    T = jnp.asarray(rng.rand(*SHAPE), jnp.float32)
    T = T.at[0].set(3.0).at[-1].set(3.0)
    T = T.at[:, 0].set(3.0).at[:, -1].set(3.0)
    T = T.at[:, :, 0].set(3.0).at[:, :, -1].set(3.0)
    T2 = T.copy()
    Ci = jnp.ones(SHAPE, jnp.float32)
    got = np.asarray(diffusion3d.diffusion3d_step(
        T2, T, Ci, 1.0, 1e-4, SC["_dx"], SC["_dy"], SC["_dz"], nsteps=4))
    np.testing.assert_array_equal(got[0], 3.0)
    np.testing.assert_array_equal(got[-1], 3.0)
    np.testing.assert_array_equal(got[:, 0], 3.0)
    np.testing.assert_array_equal(got[:, :, -1], 3.0)


def test_run_steps_2d_multi_sweep(rng):
    shape = (24, 32)
    U = jnp.asarray(rng.rand(*shape), jnp.float32)
    ps = init_parallel_stencil(backend="pallas", ndims=2)

    @ps.parallel(outputs=("U2",), rotations={"U2": "U"})
    def kern(U2, U, dt):
        return {"U2": fd2d.inn(U) + dt * (fd2d.d2_xi(U) + fd2d.d2_yi(U))}

    a, b = U.copy(), U
    for _ in range(3):
        a = kern(U2=a, U=b, dt=1e-3)
        a, b = b, a
    got = np.asarray(kern.run_steps(3, U2=U.copy(), U=U, dt=1e-3))
    np.testing.assert_array_equal(got, np.asarray(b))


def test_run_steps_requires_rotations(rng):
    ps = init_parallel_stencil(backend="jnp", ndims=2)

    @ps.parallel(outputs=("U2",))
    def kern(U2, U, dt):
        return {"U2": fd2d.inn(U) * 2.0}

    U = jnp.asarray(rng.rand(8, 8), jnp.float32)
    with pytest.raises(ValueError, match="rotations"):
        kern.run_steps(2, U2=U, U=U, dt=0.1)
    # nsteps=1 never needs rotations
    kern.run_steps(1, U2=U, U=U, dt=0.1)


# --------------------------------------------------------------------------
# blocked T_eff accounting
# --------------------------------------------------------------------------
def test_a_eff_blocked_divides_by_k():
    base = teff.a_eff(1000, n_read=2, n_write=1, itemsize=4)
    assert teff.a_eff_blocked(1000, 2, 1, 4, nsteps=1) == base
    assert teff.a_eff_blocked(1000, 2, 1, 4, nsteps=4) == base / 4


def test_halo_compute_overhead_monotone():
    """Redundant halo compute grows with k and shrinks with block size."""
    assert teff.halo_compute_overhead((32, 32, 32), 1, 1) == 0.0
    o2 = teff.halo_compute_overhead((32, 32, 32), 1, 2)
    o4 = teff.halo_compute_overhead((32, 32, 32), 1, 4)
    assert 0.0 < o2 < o4
    assert teff.halo_compute_overhead((64, 64, 64), 1, 4) < o4


# --------------------------------------------------------------------------
# autotuner
# --------------------------------------------------------------------------
def test_autotune_picks_and_caches(tmp_path):
    cache = str(tmp_path / "tune.json")
    calls = []

    def make_step(tile, k):
        def run():
            calls.append((tile, k))
            return jnp.zeros(())
        return run

    r1 = autotune.autotune(
        make_step, shape=(16, 16, 16), dtype="float32", radius=1, n_fields=3,
        nsteps_candidates=(1, 2), iters=1, tag="unit", cache_path=cache)
    assert r1.nsteps in (1, 2) and len(r1.tile) == 3
    assert r1.candidates_tried >= 2
    n_calls = len(calls)
    # second invocation: memoized, no new measurements
    r2 = autotune.autotune(
        make_step, shape=(16, 16, 16), dtype="float32", radius=1, n_fields=3,
        nsteps_candidates=(1, 2), iters=1, tag="unit", cache_path=cache)
    assert r2 == r1 and len(calls) == n_calls
    # disk cache survives a cold in-process cache
    autotune._CACHE.clear()
    r3 = autotune.autotune(
        make_step, shape=(16, 16, 16), dtype="float32", radius=1, n_fields=3,
        nsteps_candidates=(1, 2), iters=1, tag="unit", cache_path=cache)
    assert r3.tile == r1.tile and r3.nsteps == r1.nsteps
    assert len(calls) == n_calls


def test_autotune_diffusion3d_smoke():
    r = autotune.autotune_diffusion3d((16, 16, 16), nsteps_candidates=(1, 2),
                                      iters=1)
    assert r.nsteps in (1, 2) and r.per_step_s > 0
