"""Property tests for the serve queue's ordering invariants.

Two contracts the batch assembler leans on, checked over randomized
schedules rather than hand-picked examples:

* deadline expiry: a queue-expired ticket NEVER occupies a batch slot —
  it fails with ``DeadlineExceeded`` — and the unexpired requests of a
  bucket are served in strict submit (FIFO) order regardless of how the
  expired ones interleave;
* front-requeue: re-queuing an in-flight prefix (the worker-death path)
  puts it ahead of everything waiting while preserving BOTH the
  requeued tickets' relative order and the waiting tickets' relative
  order.

``hypothesis`` is an optional dependency (CI installs it; the minimal
image may not) — the module skips cleanly when absent.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serve import errors  # noqa: E402
from repro.serve.queue import RequestQueue, SolveRequest  # noqa: E402

SETTINGS = dict(max_examples=30, deadline=None)

FIELD = {"T": np.zeros((4, 4), np.float32)}


def _submit(q, expired: bool) -> "Ticket":
    # deadline_s=0.0 expires the instant it is queued; None never does
    return q.submit(SolveRequest(fields=FIELD,
                                 deadline_s=0.0 if expired else None))


def _drain(q, max_batch: int) -> list:
    batches = []
    while True:
        batch = q.take_batch(max_batch, timeout=0.0)
        if not batch:
            return batches
        batches.append(batch)


@settings(**SETTINGS)
@given(expired_mask=st.lists(st.booleans(), min_size=1, max_size=24),
       max_batch=st.integers(min_value=1, max_value=6))
def test_expired_never_occupy_slots_and_fifo_survives(expired_mask,
                                                      max_batch):
    q = RequestQueue(capacity=64)
    tickets = [_submit(q, expired) for expired in expired_mask]
    served = [t for batch in _drain(q, max_batch) for t in batch]

    unexpired = [t for t, e in zip(tickets, expired_mask) if not e]
    expired = [t for t, e in zip(tickets, expired_mask) if e]

    # every unexpired ticket served exactly once, in submit order
    assert served == unexpired
    # every expired ticket failed with the typed, located error
    for t in expired:
        assert t.done
        with pytest.raises(errors.DeadlineExceeded) as ei:
            t.result(timeout=0)
        assert ei.value.request_id == t.request.request_id
    # and the queue is fully drained
    assert len(q) == 0


@settings(**SETTINGS)
@given(n_waiting=st.integers(min_value=0, max_value=12),
       n_inflight=st.integers(min_value=1, max_value=12),
       max_batch=st.integers(min_value=1, max_value=5))
def test_front_requeue_preserves_both_orders(n_waiting, n_inflight,
                                             max_batch):
    q = RequestQueue(capacity=64)
    inflight = [_submit(q, False) for _ in range(n_inflight)]
    # a worker took the in-flight batch; these arrived while it ran
    taken = q.take_batch(n_inflight, timeout=0.0)
    assert taken == inflight
    waiting = [_submit(q, False) for _ in range(n_waiting)]

    q.requeue(inflight)     # the worker died

    served = [t for batch in _drain(q, max_batch) for t in batch]
    # requeued tickets come FIRST (they already waited once), in their
    # original relative order; the waiting tickets follow, un-reordered
    assert served == inflight + waiting


@settings(**SETTINGS)
@given(resolved_mask=st.lists(st.booleans(), min_size=1, max_size=10))
def test_requeue_skips_resolved_tickets(resolved_mask):
    q = RequestQueue(capacity=64)
    inflight = [_submit(q, False) for _ in resolved_mask]
    q.take_batch(len(inflight), timeout=0.0)
    for t, done in zip(inflight, resolved_mask):
        if done:
            t.resolve({"ok": True})
    q.requeue(inflight)
    served = [t for batch in _drain(q, 4) for t in batch]
    assert served == [t for t, done in zip(inflight, resolved_mask)
                      if not done]
