"""Stencil IR: symbolic footprint inference (per-field/per-axis halos,
staggered offsets, declared-radius cross-check), fused boundary
conditions (bitwise vs the core.boundary post-pass on both backends,
including temporal blocking), analytic cost models (exact flop/byte
counts) and the autotuner's pre-compile candidate pruning."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import boundary, fd2d, fd3d, init_parallel_stencil, teff
from repro.ir import (BoundaryCondition, StencilCostModel, TraceError,
                      count_flops, trace_stencil)
from repro.kernels import autotune
from repro.launch import roofline

SHAPE2 = (20, 24)
SHAPE3 = (16, 12, 20)


def _arr(rng, shape=SHAPE2):
    return jnp.asarray(rng.rand(*shape), jnp.float32)


def _diffusion3(ps, **kw):
    @ps.parallel(outputs=("T2",), rotations={"T2": "T"}, **kw)
    def kern(T2, T, Ci, lam, dt, _dx, _dy, _dz):
        return {"T2": fd3d.inn(T) + dt * (lam * fd3d.inn(Ci) * (
            fd3d.d2_xi(T) * _dx ** 2 + fd3d.d2_yi(T) * _dy ** 2 +
            fd3d.d2_zi(T) * _dz ** 2))}
    return kern


SC3 = dict(lam=1.0, dt=1e-4, _dx=1.0, _dy=1.0, _dz=1.0)


# --------------------------------------------------------------------------
# footprint inference
# --------------------------------------------------------------------------
def test_diffusion3d_inferred_footprint():
    """The Fig. 1 solver without any declared radius: r=1, symmetric,
    reads {T, Ci}, writes {T2}, exchange depth only for T."""
    kern = _diffusion3(init_parallel_stencil(ndims=3))
    ir = kern.stencil_ir(T2=SHAPE3, T=SHAPE3, Ci=SHAPE3, **SC3)
    assert ir.inferred_radius == 1
    assert ir.halo == ((1, 1),) * 3
    assert ir.write_modes["T2"] == ("inn",) * 3
    assert ir.write_rings["T2"] == (1, 1, 1)
    assert set(ir.read_fields) == {"T", "Ci"}
    assert ir.field_halo["T"] == ((1, 1),) * 3
    assert ir.field_halo["Ci"] == ((0, 0),) * 3   # only fd.inn -> no halo
    assert ir.io_counts() == (2, 1)


def test_gp_fused_kernel_inferred_radius2():
    """The coupled two-frame symplectic GP update (no radius declared in
    the example anymore) infers the radius-2 footprint."""
    from examples import gross_pitaevskii as gp

    cfg = gp.GPConfig(n=12)
    grid, re, im, V = gp.init_state(cfg)
    kern = gp.make_step(grid, cfg).kernels[0]
    ir = kern.stencil_ir(re2=re, im2=im, re=re, im=im, V=V, g=cfg.g,
                         dt=0.1, _dx2=1.0, _dy2=1.0, _dz2=1.0)
    assert ir.inferred_radius == 2
    assert ir.halo == ((2, 2),) * 3
    assert ir.write_rings["re2"] == (2, 2, 2)
    assert ir.write_rings["im2"] == (2, 2, 2)
    # per-FIELD depths are finer than the scalar radius: only im is read
    # two cells past the write position (through lap(re1)); re and V one.
    assert ir.field_halo["im"] == ((2, 2),) * 3
    assert ir.field_halo["re"] == ((1, 1),) * 3
    assert ir.field_halo["V"] == ((1, 1),) * 3


def test_porosity_flux_split_staggered_offsets():
    """The flux-split kernels infer the staggered face offsets and the
    one-sided (0, 1) halos of forward differences/averages."""
    from examples import porosity_waves as pw

    cfg = pw.PorosityConfig(n=24, flux_split=True)
    grid = pw.make_grid(cfg)
    fluxes, update = pw.make_step(grid, cfg).kernels
    n = cfg.n
    ir = fluxes.stencil_ir(qx=(n - 1, n), qy=(n, n - 1), phi=(n, n),
                           Pe=(n, n))
    assert ir.offsets["qx"] == (1, 0) and ir.offsets["qy"] == (0, 1)
    assert ir.write_modes["qx"] == ("all", "all")
    assert ir.halo == ((0, 1), (0, 1))
    assert ir.inferred_radius == 1
    ir_u = update.stencil_ir(phi2=(n, n), Pe2=(n, n), phi=(n, n),
                             Pe=(n, n), qx=(n - 1, n), qy=(n, n - 1),
                             dtau=0.0)
    assert ir_u.inferred_radius == 1
    assert ir_u.write_modes["phi2"] == ("inn", "inn")


def test_asymmetric_upwind_halo_and_parity(rng):
    """A one-sided (upwind) difference infers halo (1,0)/(0,0) — the VMEM
    window shrinks — and the backends still agree."""
    def upwind(T2, T, dt):
        return {"T2": fd2d.inn(T) + dt * (T[:-2, 1:-1] - T[1:-1, 1:-1])}

    U = _arr(rng)
    outs = {}
    for backend in ("jnp", "pallas"):
        ps = init_parallel_stencil(backend=backend, ndims=2)
        k = ps.parallel(outputs=("T2",))(upwind)
        outs[backend] = np.asarray(k(T2=U, T=U, dt=1e-3))
        ir = k.stencil_ir(T2=SHAPE2, T=SHAPE2, dt=0.0)
        assert ir.halo == ((1, 0), (0, 0))
    np.testing.assert_allclose(outs["jnp"], outs["pallas"], atol=1e-6)
    # The *window* halo is max(read halo, write ring) per side: the inn
    # write ring is 1, so the window extends one cell on every side even
    # where the data footprint is shallower — without that, the update
    # expression cannot reach the seam cells of interior blocks (the
    # data footprint stays (1,0)/(0,0) and is what the halo exchange
    # uses; the window inflation is a structural placement requirement).
    ps = init_parallel_stencil(backend="pallas", ndims=2)
    k = ps.parallel(outputs=("T2",))(upwind)
    k(T2=U, T=U, dt=1e-3)
    run = next(iter(k._cache.values()))
    assert run.halo == ((1, 1), (1, 1))
    symmetric = 2 * (SHAPE2[0] + 2) * (SHAPE2[1] + 2) * 4
    assert run.window_bytes <= symmetric


def test_asymmetric_upwind_multiblock_seams(rng):
    """Regression: with more than one block per axis, seam cells whose
    update index falls outside the tight data-footprint window used to be
    silently dropped (masked valid but zero-padded). The ring-covering
    window geometry must make every tiling agree with the jnp backend."""
    def upwind(T2, T, dt):
        return {"T2": fd2d.inn(T) + dt * (T[:-2, 1:-1] - T[1:-1, 1:-1])}

    U = _arr(rng)
    ps = init_parallel_stencil(backend="jnp", ndims=2)
    want = np.asarray(ps.parallel(outputs=("T2",))(upwind)(T2=U, T=U, dt=1e-3))
    for tile in ((4, 4), (10, 8)):
        ps = init_parallel_stencil(backend="pallas", ndims=2)
        k = ps.parallel(outputs=("T2",), tile=tile)(upwind)
        got = np.asarray(k(T2=U, T=U, dt=1e-3))
        np.testing.assert_allclose(got, want, atol=1e-6)


def test_inferred_zero_halo_axis_run_steps_bitwise(rng):
    """An axis the kernel never differences costs no halo; temporal
    blocking still matches k sequential calls bit-for-bit."""
    def xonly(T2, T, dt):
        return {"T2": T[1:-1, :]
                      + dt * (T[2:, :] - 2.0 * T[1:-1, :] + T[:-2, :])}

    U = _arr(rng)
    for backend in ("jnp", "pallas"):
        ps = init_parallel_stencil(backend=backend, ndims=2)
        k = ps.parallel(outputs=("T2",), rotations={"T2": "T"})(xonly)
        ir = k.stencil_ir(T2=SHAPE2, T=SHAPE2, dt=0.0)
        assert ir.halo == ((1, 1), (0, 0))
        a, b = U.copy(), U
        for _ in range(3):
            a = k(T2=a, T=b, dt=1e-3)
            a, b = b, a
        got = np.asarray(k.run_steps(3, T2=U.copy(), T=U, dt=1e-3))
        np.testing.assert_array_equal(got, np.asarray(b))


def test_declared_radius_cross_check_raises(rng):
    ps = init_parallel_stencil(ndims=2)

    @ps.parallel(outputs=("T2",), radius=2)
    def k(T2, T, dt):
        return {"T2": fd2d.inn(T) + dt * (fd2d.d2_xi(T) + fd2d.d2_yi(T))}

    with pytest.raises(ValueError, match="declared radius=2 does not match"):
        k(T2=_arr(rng), T=_arr(rng), dt=1e-3)


def test_matching_declared_radius_accepted(rng):
    ps = init_parallel_stencil(ndims=3)
    kern = _diffusion3(ps, radius=1)
    U = _arr(rng, SHAPE3)
    kern(T2=U, T=U, Ci=U, **SC3)  # no error: inferred == declared


def test_untraceable_update_falls_back_or_raises(rng):
    def weird(T2, T, dt):
        return {"T2": jnp.maximum(fd2d.inn(T), 0.1) * (1.0 + dt)}

    U = _arr(rng)
    outs = {}
    for backend in ("jnp", "pallas"):
        ps = init_parallel_stencil(backend=backend, ndims=2)
        k = ps.parallel(outputs=("T2",), radius=1)(weird)  # legacy fallback
        outs[backend] = np.asarray(k(T2=U, T=U, dt=1e-3))
    np.testing.assert_allclose(outs["jnp"], outs["pallas"], atol=1e-6)
    ps = init_parallel_stencil(ndims=2)
    k = ps.parallel(outputs=("T2",))(weird)  # no radius to fall back on
    with pytest.raises(ValueError, match="footprint inference failed"):
        k(T2=U, T=U, dt=1e-3)


def test_trace_error_surface():
    def int_index(f, s):
        return {"T2": f["T"][0]}

    with pytest.raises(TraceError, match="unit-stride"):
        trace_stencil(int_index, {"T2": (8, 8), "T": (8, 8)}, ("T2",))

    def strided(f, s):
        return {"T2": f["T"][::2, :]}

    with pytest.raises(TraceError, match="strided"):
        trace_stencil(strided, {"T2": (8, 8), "T": (8, 8)}, ("T2",))

    def mismatch(f, s):
        return {"T2": f["T"][1:, :] + f["T"][:, 1:]}

    with pytest.raises(TraceError, match="shape mismatch"):
        trace_stencil(mismatch, {"T2": (8, 8), "T": (8, 8)}, ("T2",))


def test_staggered_interior_write_rejected_at_trace():
    def bad(f, s):
        return {"q2": f["q"][1:-1, 1:-1]}

    with pytest.raises(ValueError, match="staggered along axis 0"):
        trace_stencil(bad, {"q2": (7, 8), "q": (7, 8), "T": (8, 8)}, ("q2",))


def test_staggered_output_halo_covers_block_seams(rng):
    """A staggered `all`-write output whose reads are shallower than its
    offset still needs a window wide enough to cover every block frame:
    the inferred hi halo includes the output's own staggering, so small
    tiles produce no zero-padded seam columns (regression: inferred halo
    (0,0) left block-boundary rows garbage on the pallas backend)."""
    def stag(qx, A):
        return {"qx": A[:-1, :] * 2.0}

    n, m = 16, 16
    A = _arr(rng, (n, m))
    qx0 = jnp.zeros((n - 1, m), jnp.float32)
    outs = {}
    for backend in ("jnp", "pallas"):
        ps = init_parallel_stencil(backend=backend, ndims=2)
        k = ps.parallel(outputs=("qx",), tile=(4, 16))(stag)
        ir = k.stencil_ir(qx=(n - 1, m), A=(n, m))
        assert ir.halo == ((0, 1), (0, 0))  # hi side covers the offset
        assert ir.inferred_radius == 1
        outs[backend] = np.asarray(k(qx=qx0, A=A))
    np.testing.assert_allclose(outs["jnp"], outs["pallas"], atol=1e-6)
    np.testing.assert_allclose(outs["jnp"], 2.0 * np.asarray(A)[:-1, :],
                               atol=1e-6)


# --------------------------------------------------------------------------
# fused boundary conditions
# --------------------------------------------------------------------------
_BC_CASES = [
    ("dirichlet", BoundaryCondition("dirichlet", value=0.5),
     lambda a: boundary.dirichlet(a, 0.5)),
    ("neumann0", BoundaryCondition("neumann0"), boundary.neumann0),
    ("periodic", BoundaryCondition("periodic"), boundary.periodic),
    ("neumann0_d2", BoundaryCondition("neumann0", depth=2),
     lambda a: boundary.neumann0(a, depth=2)),
    ("dirichlet_ax0", BoundaryCondition("dirichlet", value=1.5, axes=(0,)),
     lambda a: boundary.dirichlet(a, 1.5, axes=(0,))),
]


def _bc_kernel(ps, bcobj=None):
    kw = {} if bcobj is None else {"bc": {"U2": bcobj}}
    @ps.parallel(outputs=("U2",), rotations={"U2": "U"}, **kw)
    def kern(U2, U, dt):
        return {"U2": fd2d.inn(U) + dt * (fd2d.d2_xi(U) + fd2d.d2_yi(U))}
    return kern


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("case", _BC_CASES, ids=[c[0] for c in _BC_CASES])
def test_fused_bc_bitwise_equals_postpass(backend, case, rng):
    """Engine-fused boundary conditions == kernel-without-bc followed by
    the core.boundary post-pass, bit for bit, on both backends."""
    _, bcobj, post = case
    U = _arr(rng)
    ps = init_parallel_stencil(backend=backend, ndims=2)
    got = np.asarray(_bc_kernel(ps, bcobj)(U2=U, U=U, dt=1e-3))
    want = np.asarray(post(_bc_kernel(ps)(U2=U, U=U, dt=1e-3)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("case", _BC_CASES, ids=[c[0] for c in _BC_CASES])
def test_fused_bc_run_steps_bitwise(backend, case, rng):
    """BCs compose with nsteps=k temporal blocking: the fused k-step path
    (in-kernel BC between sweeps; sequential-launch fallback for
    periodic) == k sequential bc-steps, bit for bit."""
    _, bcobj, _ = case
    U = _arr(rng)
    ps = init_parallel_stencil(backend=backend, ndims=2)
    kern = _bc_kernel(ps, bcobj)
    a, b = U.copy(), U
    for _ in range(3):
        a = kern(U2=a, U=b, dt=1e-3)
        a, b = b, a
    got = np.asarray(kern.run_steps(3, U2=U.copy(), U=U, dt=1e-3))
    np.testing.assert_array_equal(got, np.asarray(b))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_fused_bc_radius2_depth1(backend, rng):
    """BC depth smaller than the write ring: the outermost layer takes
    the condition, the rest of the unwritten ring keeps prev values —
    exactly the post-pass semantics."""
    def k2(U2, U, dt):
        d4 = (-U[4:, 2:-2] + 16 * U[3:-1, 2:-2] - 30 * U[2:-2, 2:-2]
              + 16 * U[1:-3, 2:-2] - U[:-4, 2:-2]) / 12.0
        d2 = U[2:-2, 3:-1] - 2 * U[2:-2, 2:-2] + U[2:-2, 1:-3]
        return {"U2": U[2:-2, 2:-2] + dt * (d4 + d2)}

    U = _arr(rng)
    ps = init_parallel_stencil(backend=backend, ndims=2)
    kb = ps.parallel(outputs=("U2",), bc={"U2": BoundaryCondition("neumann0")})(k2)
    kr = ps.parallel(outputs=("U2",))(k2)
    got = np.asarray(kb(U2=U, U=U, dt=1e-3))
    want = np.asarray(boundary.neumann0(kr(U2=U, U=U, dt=1e-3)))
    np.testing.assert_array_equal(got, want)


def test_bc_validation_errors(rng):
    ps = init_parallel_stencil(ndims=2)
    with pytest.raises(ValueError, match="not an output"):
        _ = ps.parallel(outputs=("U2",), bc={"U": BoundaryCondition("neumann0")})(
            lambda U2, U: {"U2": 2.0 * U})
    with pytest.raises(ValueError, match="must be one of"):
        BoundaryCondition("mirror")
    k = ps.parallel(outputs=("U2",),
                    bc={"U2": BoundaryCondition("neumann0", depth=4)})(
        lambda U2, U: {"U2": 2.0 * U})
    small = jnp.zeros((6, 6), jnp.float32)
    with pytest.raises(ValueError, match="smaller than"):
        k(U2=small, U=small)


# --------------------------------------------------------------------------
# analytic cost models
# --------------------------------------------------------------------------
def test_diffusion_flop_count_exact():
    """Fig. 1 diffusion: 18 flops per interior point (9 adds + 9 muls),
    shared subexpressions counted once."""
    kern = _diffusion3(init_parallel_stencil(ndims=3))
    cost = kern.cost_model(T2=SHAPE3, T=SHAPE3, Ci=SHAPE3, **SC3)
    n_int = np.prod([s - 2 for s in SHAPE3])
    assert cost.flops.adds == 9 * n_int
    assert cost.flops.muls == 9 * n_int
    assert cost.flops.total() == 18 * n_int


def test_a_eff_from_ir_matches_hand_count():
    """The IR-derived A_eff reproduces the paper's hand count for the
    diffusion solver (2 reads + 1 write) and divides by k when blocked."""
    kern = _diffusion3(init_parallel_stencil(ndims=3))
    ir = kern.stencil_ir(T2=SHAPE3, T=SHAPE3, Ci=SHAPE3, **SC3)
    n = int(np.prod(SHAPE3))
    hand = teff.a_eff(n, n_read=2, n_write=1, itemsize=4)
    assert teff.a_eff_from_ir(ir, itemsize=4) == hand
    assert teff.a_eff_from_ir(ir, itemsize=4, nsteps=4) == hand / 4
    assert teff.io_counts_from_ir(ir) == (2, 1)


def test_shared_subexpression_counted_once():
    def kern(f, s):
        c = f["T"][1:-1, 1:-1]
        return {"T2": (c + c * c) + 0.0 * c}

    ir = trace_stencil(kern, {"T2": (10, 10), "T": (10, 10)}, ("T2",))
    fc = count_flops(ir.exprs)
    n = 8 * 8
    # c*c (mul), 0.0*c (mul), two adds — the slice node c counted once, free
    assert fc.muls == 2 * n and fc.adds == 2 * n


def test_cost_model_roofline_position():
    kern = _diffusion3(init_parallel_stencil(ndims=3))
    cost = kern.cost_model(T2=SHAPE3, T=SHAPE3, Ci=SHAPE3, **SC3)
    rec = roofline.stencil_roofline(cost, nsteps=1, hw=teff.TPU_V5E)
    assert rec["dominant"] == "memory"   # stencils sit far left of ridge
    assert rec["intensity_flop_per_byte"] < rec["ridge_flop_per_byte"]
    assert rec["bytes_per_step"] == cost.read_bytes + cost.write_bytes
    rec4 = roofline.stencil_roofline(cost, nsteps=4, hw=teff.TPU_V5E)
    assert rec4["bytes_per_step"] == rec["bytes_per_step"] / 4


def test_cost_model_predicts_halo_overhead():
    cost = StencilCostModel(
        shape=(64, 64), itemsize=4, flops=count_flops({}),
        read_bytes=64 * 64 * 4 * 2, write_bytes=64 * 64 * 4,
        halo=((1, 1), (1, 1)), field_offsets=((0, 0), (0, 0)))
    # deeper blocking on smaller tiles fetches relatively more halo
    f_big = cost.fetched_bytes_per_step((64, 64), 1)
    f_small_k4 = cost.fetched_bytes_per_step((8, 8), 4)
    per_point_big = f_big / (64 * 64)
    per_point_small = f_small_k4 / (64 * 64)
    assert per_point_small > per_point_big / 4  # halo overhead eats the /k


# --------------------------------------------------------------------------
# autotune pruning via the analytic model
# --------------------------------------------------------------------------
def test_autotune_prunes_candidates_before_compiling():
    """With a cost model + hardware spec, predicted-slow (tile, k)
    configs are dropped before make_step is ever called for them."""
    built = []

    def make_step(tile, k):
        built.append((tuple(tile), k))
        return lambda: jnp.zeros(())

    shape = (64, 64)
    # 3 fields, radius-1 symmetric halo: tiny tiles fetch ~2x the bytes
    cost = StencilCostModel(
        shape=shape, itemsize=4, flops=count_flops({}),
        read_bytes=2 * 64 * 64 * 4, write_bytes=64 * 64 * 4,
        halo=((1, 1), (1, 1)),
        field_offsets=((0, 0), (0, 0), (0, 0)))
    tiles = [(64, 64), (2, 64), (2, 2)]
    r = autotune.autotune(
        make_step, shape=shape, dtype="float32", radius=1, n_fields=3,
        nsteps_candidates=(1,), tiles=tiles, iters=1, tag="prune-unit",
        cost_model=cost, hw=teff.TPU_V5E, prune_ratio=1.2)
    assert r.candidates_pruned >= 1
    assert len(built) == 3 - r.candidates_pruned  # pruned: never built
    assert (2, 2) not in [t for t, _ in built]    # the worst tile never ran
    assert r.tile == (64, 64)


def test_autotune_prune_key_distinct_from_unpruned():
    k1 = autotune.cache_key((8, 8), "float32", 1, 3, "t", (1,))
    k2 = autotune.cache_key((8, 8), "float32", 1, 3, "t", (1,),
                            prune=("TPU v5e", 2.0))
    assert k1 != k2


# --------------------------------------------------------------------------
# exchange-depth tightening hooks
# --------------------------------------------------------------------------
def test_field_halo_drives_exchange_depths():
    """Fields the kernel reads shallowly (or not at all) get shallower
    (or zero) exchange depths — the IR data the distributed layer uses."""
    kern = _diffusion3(init_parallel_stencil(ndims=3))
    ir = kern.stencil_ir(T2=SHAPE3, T=SHAPE3, Ci=SHAPE3, **SC3)
    assert ir.field_halo["T"] == ((1, 1),) * 3
    assert ir.field_halo["Ci"] == ((0, 0),) * 3
    assert ir.field_halo["T2"] == ((0, 0),) * 3  # outputs are write-only
