"""Runtime telemetry: no-op-when-disabled, JSONL/Perfetto schema,
device-metric harvesting at existing sync points, roofline attribution.

The two contracts under test:
  * disabled mode is FREE — the global collector is the shared no-op
    singleton and the traced solve program is byte-identical with
    telemetry on or off (zero-host-sync rule);
  * enabled mode writes schema-valid JSONL whose records carry the
    chunk spans / error trajectory / checkpoint latencies / roofline
    fractions the observability issue names.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import telemetry
from repro.core import fd3d, init_parallel_stencil, iterate, teff
from repro.distributed import fault, halo
from repro.telemetry import attrib, export, report, schema

ERR = {"err": "max_abs_diff(T2, T)"}


@pytest.fixture(autouse=True)
def _clean_telemetry():
    """Every test starts and ends with the env-default (disabled) state."""
    telemetry.reset()
    yield
    telemetry.reset()


def diffusion_kernel(reductions=ERR):
    ps = init_parallel_stencil(backend="jnp", ndims=3)

    @ps.parallel(outputs=("T2",), rotations={"T2": "T"},
                 reductions=reductions)
    def kern(T2, T, Ci, lam, dt, _dx, _dy, _dz):
        return {"T2": fd3d.inn(T) + dt * (lam * fd3d.inn(Ci) * (
            fd3d.d2_xi(T) * _dx ** 2 + fd3d.d2_yi(T) * _dy ** 2 +
            fd3d.d2_zi(T) * _dz ** 2))}

    return kern


def setup3d(rng, shape=(12, 12, 12)):
    T = jnp.asarray(rng.rand(*shape), jnp.float32)
    Ci = jnp.asarray(rng.rand(*shape) + 0.5, jnp.float32)
    sc = dict(lam=1.0, dt=0.05, _dx=1.0, _dy=1.0, _dz=1.0)
    return T, Ci, sc


# ---------------------------------------------------------------- disabled
def test_disabled_is_shared_noop_singleton(monkeypatch):
    monkeypatch.delenv("REPRO_TELEMETRY", raising=False)
    telemetry.reset()
    col = telemetry.get()
    assert col is telemetry.NULL and not col.enabled
    assert telemetry.get() is col                 # cached, not re-resolved
    # span() hands back ONE shared reusable null context manager
    s1, s2 = col.span("a"), col.span("b", attr=1)
    assert s1 is s2
    with s1 as s:
        assert s is s1
    # every no-op path returns None and records nothing
    assert col.count("c") is None and col.gauge("g", 1.0) is None
    assert col.observe("h", 0.5) is None and col.event("e") is None
    col.span_end("x", 0.0, 1.0)
    col.flush(), col.close()
    # module-level conveniences route through the same singleton
    telemetry.count("c"), telemetry.gauge("g", 1), telemetry.event("e")
    assert not telemetry.enabled()


def test_env_enables_and_configure_overrides(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TELEMETRY", str(tmp_path / "run.jsonl"))
    telemetry.reset()
    col = telemetry.get()
    assert col.enabled and col.path == str(tmp_path / "run.jsonl")
    col2 = telemetry.configure(None)       # programmatic override
    assert telemetry.get() is col2 and col2.path is None
    telemetry.reset()
    monkeypatch.setenv("REPRO_TELEMETRY", "0")
    telemetry.reset()
    assert telemetry.get() is telemetry.NULL


def test_resolve_kwarg_contract():
    assert telemetry.resolve(False) is telemetry.NULL
    col = telemetry.Collector(None)
    assert telemetry.resolve(col) is col
    assert telemetry.resolve(None) is telemetry.get()
    forced = telemetry.resolve(True)
    assert forced.enabled


def test_traced_program_identical_on_off(rng):
    """Zero-host-sync rule, jaxpr-asserted: the solver's traced program
    does not change when a collector is active."""
    T, Ci, sc = setup3d(rng, shape=(8, 8, 8))
    kern = diffusion_kernel()
    args = (dict(T2=T, T=T, Ci=Ci), 1e-5, 50)
    off = str(jax.make_jaxpr(iterate.make_solver(kern, sc, check_every=2))(
        *args))
    telemetry.configure(None)              # enabled, in-memory
    on = str(jax.make_jaxpr(iterate.make_solver(kern, sc, check_every=2))(
        *args))
    assert on == off
    assert "callback" not in on and "outside_call" not in on


def test_disabled_solve_unperturbed(rng):
    T, Ci, sc = setup3d(rng)
    kern = diffusion_kernel()
    r0 = iterate.solve_until(kern, dict(T2=T, T=T, Ci=Ci), sc, tol=2e-5,
                             max_iters=200, check_every=5, telemetry=False)
    col = telemetry.Collector(None)
    r1 = iterate.solve_until(kern, dict(T2=T, T=T, Ci=Ci), sc, tol=2e-5,
                             max_iters=200, check_every=5, telemetry=col)
    # instrumented run: same math, bit-identical result
    assert int(r0.iters) == int(r1.iters)
    np.testing.assert_array_equal(np.asarray(r0.fields["T"]),
                                  np.asarray(r1.fields["T"]))
    assert any(r["kind"] == "span" and r["name"] == "solve_until"
               for r in col.records)


def test_solver_cache_reused_across_calls(rng):
    T, Ci, sc = setup3d(rng, shape=(8, 8, 8))
    kern = diffusion_kernel()
    s1 = iterate._jitted_solver(kern, sc, check_every=5, error=None,
                                until="below")
    s2 = iterate._jitted_solver(kern, sc, check_every=5, error=None,
                                until="below")
    assert s1 is s2
    s3 = iterate._jitted_solver(kern, sc, check_every=3, error=None,
                                until="below")
    assert s3 is not s1
    # unhashable scalars (mutable numpy buffer) opt out of the cache
    s4 = iterate._jitted_solver(kern, dict(sc, lam=np.array(1.0)),
                                check_every=5, error=None, until="below")
    assert s4 is not s1


# ----------------------------------------------------------------- enabled
def _run_checkpointed(rng, tmp_path, log="run.jsonl"):
    T, Ci, sc = setup3d(rng)
    kern = diffusion_kernel()
    path = str(tmp_path / log)
    # the GLOBAL collector, as REPRO_TELEMETRY= would install it: the
    # checkpoint/fault subsystems emit through the process singleton
    col = telemetry.configure(path)
    mon = fault.StepMonitor(host_id=0, heartbeat_dir=str(tmp_path / "hb"))
    ck = iterate.Checkpointing(str(tmp_path / "ck"), save_every=2,
                               blocking=False, monitor=mon)
    res = iterate.solve_until(kern, dict(T2=T, T=T, Ci=Ci), sc, tol=2e-5,
                              max_iters=200, check_every=5, checkpoint=ck)
    telemetry.reset()                       # close + flush the log
    return res, col, path


def test_enabled_chunked_jsonl_schema_and_content(rng, tmp_path):
    res, col, path = _run_checkpointed(rng, tmp_path)
    counts = schema.validate_file(path)          # raises on any drift
    assert counts["meta"] == 1 and counts["span"] > 0
    records = schema.load_records(path)
    names = {(r["kind"], r.get("name")) for r in records}
    assert ("span", "solve.chunk") in names
    assert ("span", "checkpoint.snapshot") in names
    assert ("span", "checkpoint.write") in names
    assert ("event", "solve.trajectory") in names
    assert ("event", "roofline") in names
    assert ("counter", "solve.steps") in names
    assert ("counter", "checkpoint.saves") in names
    assert ("gauge", "fault.ewma_step_s") in names
    # chunk spans carry the boundary harvest; steps sum to the iter count
    chunks = [r for r in records
              if r["kind"] == "span" and r["name"] == "solve.chunk"]
    assert sum(c["attrs"]["steps"] for c in chunks) == int(res.iters)
    assert chunks[0]["attrs"]["cold"] is True
    traj = [r for r in records
            if r["kind"] == "event" and r["name"] == "solve.trajectory"]
    errs = [t["attrs"]["err"] for t in traj]
    assert errs[-1] == pytest.approx(float(res.err))
    assert all(e >= errs[-1] for e in errs[:1])  # diffusion decays
    # roofline attribution present with a sane fraction
    roof = [r for r in records
            if r["kind"] == "event" and r["name"] == "roofline"]
    assert 0 < roof[-1]["attrs"]["roofline_fraction"]
    # StepMonitor surfaced on the result
    assert res.step_stats is not None and 0 in res.step_stats
    assert res.step_stats[0]["ewma_s"] > 0


def test_resume_event_and_restore_span(rng, tmp_path):
    T, Ci, sc = setup3d(rng)
    kern = diffusion_kernel()
    ck = iterate.Checkpointing(str(tmp_path / "ck"), save_every=1,
                               blocking=True)
    iterate.solve_until(kern, dict(T2=T, T=T, Ci=Ci), sc, tol=0.0,
                        max_iters=10, check_every=5, checkpoint=ck,
                        telemetry=False)
    col2 = telemetry.configure(None)        # restore emits via the global
    res = iterate.solve_until(kern, dict(T2=T, T=T, Ci=Ci), sc, tol=0.0,
                              max_iters=20, check_every=5, checkpoint=ck)
    assert res.resumed_from == 10
    ev = [r for r in col2.records
          if r["kind"] == "event" and r["name"] == "solve.resume"]
    assert ev and ev[0]["attrs"]["step"] == 10
    assert any(r["kind"] == "span" and r["name"] == "checkpoint.restore"
               for r in col2.records)
    assert any(r["kind"] == "counter" and r["name"] == "checkpoint.restores"
               for r in col2.records)


def test_chrome_trace_export(rng, tmp_path):
    _, _, path = _run_checkpointed(rng, tmp_path)
    records = schema.load_records(path)
    out = str(tmp_path / "trace.json")
    n = export.write_chrome_trace(records, out)
    trace = json.load(open(out))
    evs = trace["traceEvents"]
    assert n == len(evs) > 0
    phases = {e["ph"] for e in evs}
    assert "X" in phases and "C" in phases     # spans + counters
    for e in evs:
        if e["ph"] == "X":
            assert e["dur"] >= 0 and e["ts"] >= 0
    assert any(e["ph"] == "M" and e["name"] == "process_name" for e in evs)


def test_prometheus_export():
    col = telemetry.Collector(None)
    col.count("solve.steps", 30)
    col.count("solve.steps", 12)
    col.gauge("roofline.fraction", 0.83, kernel="kern")
    col.observe("chunk_s", 0.1)
    col.observe("chunk_s", 0.3)
    text = export.prometheus_text(col)
    assert "repro_solve_steps_total 42" in text
    assert 'repro_roofline_fraction{kernel="kern"} 0.83' in text
    assert 'quantile="0.5"' in text and "repro_chunk_s_count 2" in text


def test_schema_rejects_drift(tmp_path):
    bad = tmp_path / "bad.jsonl"
    bad.write_text(json.dumps({"kind": "meta", "ts": 0.0, "schema": 1,
                               "pid": 1}) + "\n" +
                   json.dumps({"kind": "span", "ts": 1.0, "name": "x",
                               "dur_s": -2.0}) + "\n")
    with pytest.raises(schema.SchemaError, match="dur_s"):
        schema.validate_file(str(bad))
    # CLI surface: exit 1 + INVALID verdict
    assert schema.main([str(bad)]) == 1


def test_report_cli(rng, tmp_path, capsys):
    _, _, path = _run_checkpointed(rng, tmp_path)
    trace = str(tmp_path / "trace.json")
    assert report.main([path, "--validate", "--trace", trace]) == 0
    out = capsys.readouterr().out
    assert "Per-phase spans" in out
    assert "solve.chunk" in out
    assert "Error trajectory" in out
    assert os.path.exists(trace)


# ---------------------------------------------------------------- roofline
def test_roofline_fraction_hand_computed(rng):
    """roofline_fraction on the 3-D diffusion kernel against hand math:
    frac = t_model / t_measured and t_eff_measured = A_eff / t_measured,
    with an explicit HardwareSpec so nothing depends on the host."""
    shape = (16, 16, 16)
    kern = diffusion_kernel()
    sc = dict(lam=1.0, dt=0.05, _dx=1.0, _dy=1.0, _dz=1.0)
    cost = kern.cost_model(T2=shape, T=shape, Ci=shape, **sc)
    hw = teff.HardwareSpec("unit", peak_bw=100e9, peak_flops=1e12)
    per_step_s = 1e-3
    col = telemetry.Collector(None)
    out = attrib.attribute(col, "kern", per_step_s, cost, hw=hw)
    t_model = cost.predict_per_step_s(shape, 1, hw)
    a = cost.a_eff_bytes(1)
    assert out["roofline_fraction"] == pytest.approx(t_model / per_step_s)
    assert out["t_eff_measured"] == pytest.approx(a / per_step_s)
    assert out["t_eff_model"] == pytest.approx(a / t_model)
    gauges = [r for r in col.records if r["kind"] == "gauge"]
    byname = {g["name"]: g for g in gauges}
    assert byname["roofline.fraction"]["value"] == pytest.approx(
        t_model / per_step_s)
    assert byname["roofline.fraction"]["labels"] == {"kernel": "kern"}
    assert attrib.attribute(col, "kern", 0.0, cost, hw=hw) == {}


def test_default_hardware_env_pin(monkeypatch):
    monkeypatch.setenv("REPRO_TELEMETRY_BW_GBS", "123")
    monkeypatch.setenv("REPRO_TELEMETRY_FLOPS_G", "456")
    attrib.reset_hardware_cache()
    hw = attrib.default_hardware()
    assert hw.peak_bw == pytest.approx(123e9)
    assert hw.peak_flops == pytest.approx(456e9)
    assert attrib.default_hardware() is hw       # cached
    attrib.reset_hardware_cache()


# ------------------------------------------------------------- halo bytes
def test_exchange_byte_counts_hand_checked():
    shapes = {"A": (8, 6), "B": (8, 6)}
    isz = {"A": 4, "B": 4}
    isf = {"A": True, "B": True}
    # one mesh axis, radius 1, grouped: per side one message of
    # 2 fields * 1 plane * 6 elems = 12 f32 -> 48 B; two sides
    c = halo.exchange_byte_counts(shapes, isz, isf, n_axes=1)
    assert c == {"bytes_raw": 96, "bytes_wire": 96, "messages": 2}
    # bf16 wire: 2 B/elt
    c = halo.exchange_byte_counts(shapes, isz, isf, 1, compress="bf16")
    assert c["bytes_raw"] == 96 and c["bytes_wire"] == 48
    # int8 wire: BLOCK-padded q payload + one f32 scale per block,
    # and a second message (the scales) per slab
    from repro.distributed.compression import BLOCK
    c = halo.exchange_byte_counts(shapes, isz, isf, 1, compress="int8")
    assert c["bytes_wire"] == 2 * (BLOCK + 4)
    assert c["messages"] == 4
    # inactive axes ship nothing
    c = halo.exchange_byte_counts(shapes, isz, isf, 1, active=[False])
    assert c == {"bytes_raw": 0, "bytes_wire": 0, "messages": 0}
    # ungrouped: one message per field per side
    c = halo.exchange_byte_counts(shapes, isz, isf, 1, grouped=False)
    assert c["messages"] == 4 and c["bytes_raw"] == 96
    # int-typed fields never compress
    c = halo.exchange_byte_counts({"M": (8, 6)}, {"M": 1}, {"M": False}, 1,
                                  compress="bf16")
    assert c["bytes_wire"] == c["bytes_raw"] == 12


def test_exchange_telemetry_emission():
    """The instrumentation hook itself: analytic counts from static
    shapes, no device work (outside shard_map the axis probe fails ->
    every axis is assumed active)."""
    col = telemetry.Collector(None)
    A = jnp.ones((8, 6), jnp.float32)
    halo._emit_exchange_telemetry(col, dict(A=A), ("A",), ("x",),
                                  radius=1, depths=None, compress=None,
                                  grouped=True)
    ev = [r for r in col.records
          if r["kind"] == "event" and r["name"] == "halo.exchange_traced"]
    assert ev
    a = ev[-1]["attrs"]
    # one plane of 6 f32 per side, two sides: 48 raw bytes, 2 messages
    assert a["bytes_raw"] == a["bytes_wire"] == 48
    assert a["messages"] == 2 and a["fields"] == ["A"]
    assert any(r["kind"] == "counter" and r["name"] == "halo.traced_exchanges"
               for r in col.records)
    assert any(r["kind"] == "gauge"
               and r["name"] == "halo.bytes_wire_per_exchange"
               for r in col.records)


# ---------------------------------------------------------------- autotune
def test_autotune_decision_events():
    from repro.kernels import autotune

    telemetry.configure(None)
    col = telemetry.get()

    def make_step(tile, k):
        return lambda: jnp.zeros(())

    kw = dict(shape=(32, 32), dtype="float32", radius=1, n_fields=3,
              nsteps_candidates=(1,), tiles=[(32, 32), (8, 32)], iters=1,
              tag="telemetry-unit")
    autotune.autotune(make_step, **kw)
    autotune.autotune(make_step, **kw)
    evs = [r["attrs"]["cache"] for r in col.records
           if r["kind"] == "event" and r["name"] == "autotune.decision"]
    assert evs[0] == "miss" and "memory_hit" in evs[1:]
    miss = [r for r in col.records
            if r["kind"] == "event" and r["name"] == "autotune.decision"
            and r["attrs"]["cache"] == "miss"][0]
    assert miss["attrs"]["candidates_tried"] == 2


# -------------------------------------------------------------- percentiles
def test_measurement_percentiles():
    samples = [0.1, 0.2, 0.3, 0.4, 1.0]
    m = teff.Measurement(median_s=0.3, ci95_s=(0.1, 1.0), samples_s=samples)
    assert m.p50_s == pytest.approx(0.3)
    assert m.max_s == pytest.approx(1.0)
    assert m.mean_s == pytest.approx(0.4)
    assert m.p50_s <= m.p90_s <= m.max_s
    p = m.percentiles()
    assert set(p) == {"mean_s", "p50_s", "p90_s", "max_s"}


def test_measure_exposes_percentiles():
    m = teff.measure(lambda: jnp.zeros(8) + 1, iters=5, warmup=1)
    assert len(m.samples_s) == 5
    assert m.p50_s <= m.max_s
    assert m.percentiles()["max_s"] == max(m.samples_s)


# ----------------------------------------------------------------- overhead
def test_telemetry_overhead_under_2pct(rng):
    """Acceptance bound: <2% per-step overhead with telemetry on at 128^3
    on the jnp backend. The traced program is identical (asserted above),
    so the only added cost is a handful of host-side record appends per
    solve; min-over-samples comparison with retries keeps the check
    robust to shared-host noise."""
    shape = (128, 128, 128)
    T = jnp.asarray(rng.rand(*shape), jnp.float32)
    Ci = jnp.ones(shape, jnp.float32)
    sc = dict(lam=1.0, dt=1e-3, _dx=1.0, _dy=1.0, _dz=1.0)
    kern = diffusion_kernel()
    fields = dict(T2=T, T=T, Ci=Ci)
    col = telemetry.Collector(None)
    attrib.default_hardware()   # resolve the STREAM peak outside the timing

    def run(sel):
        res = iterate.solve_until(kern, fields, sc, tol=0.0, max_iters=20,
                                  check_every=5, telemetry=sel)
        jax.block_until_ready(res.err)

    run(False), run(col)        # warm: compile once, AOT-compile once
    last = None
    for _ in range(3):          # retry against host noise
        off, on = [], []
        import time
        for _ in range(4):      # interleaved: both see the same drift
            t0 = time.perf_counter(); run(False)
            off.append(time.perf_counter() - t0)
            t0 = time.perf_counter(); run(col)
            on.append(time.perf_counter() - t0)
        last = min(on) / min(off) - 1.0
        if last < 0.02:
            break
    assert last < 0.02, f"telemetry overhead {last:.3%} >= 2%"


# ---------------------------------------------------------------------------
# hardened JSONL writer: transient I/O degrades to dropped-records-with-
# counter instead of killing the solve (serving-layer satellite)
# ---------------------------------------------------------------------------
class _FlakyIO:
    """Install/remove a FaultPlan object directly (bypassing the env) so
    the injection budget starts ticking exactly where the test says."""

    def __enter__(self):
        fault.FaultPlan.reset_active()
        return self

    def arm(self, **kw):
        plan = fault.FaultPlan(**kw)
        fault._active_plan, fault._active_loaded = plan, True
        return plan

    def __exit__(self, *exc):
        fault.FaultPlan.reset_active()
        return False


def test_writer_absorbs_transient_io_within_retry_budget(tmp_path,
                                                          monkeypatch):
    monkeypatch.setenv(fault.PLAN_ENV, fault.FaultPlan(io_errors=2).to_env())
    fault.FaultPlan.reset_active()
    path = str(tmp_path / "t.jsonl")
    col = telemetry.configure(path=path)
    for i in range(5):
        col.count("solve.steps", i)
    col.close()
    fault.FaultPlan.reset_active()
    lines = [json.loads(ln) for ln in open(path)]
    assert col.dropped_records == 0
    assert len(lines) == 6          # meta + 5 counters: nothing lost


def test_writer_drops_with_counter_when_retries_exhausted(tmp_path):
    path = str(tmp_path / "t.jsonl")
    with _FlakyIO() as flaky:
        col = telemetry.configure(path=path)
        # budget > attempts: the next write fails all its retries
        flaky.arm(io_errors=col.IO_ATTEMPTS)
        col.count("lost_line", 1)       # dropped, MUST NOT raise
        col.count("landed_line", 1)     # budget spent: lands
        col.close()
    lines = [json.loads(ln) for ln in open(path)]
    mine = {"lost_line", "landed_line"}
    names = [ln.get("name") for ln in lines
             if ln["kind"] == "counter" and ln.get("name") in mine]
    assert col.dropped_records == 1
    assert names == ["landed_line"]
    # the in-memory view is complete regardless of sink health
    mem = [r.get("name") for r in col.records
           if r["kind"] == "counter" and r.get("name") in mine]
    assert mem == ["lost_line", "landed_line"]
    telemetry.reset()


def test_writer_degrades_to_memory_only_when_open_never_succeeds(
        tmp_path, monkeypatch):
    monkeypatch.setenv(fault.PLAN_ENV,
                       fault.FaultPlan(io_errors=50).to_env())
    fault.FaultPlan.reset_active()
    path = str(tmp_path / "never.jsonl")
    col = telemetry.configure(path=path)     # open exhausts retries
    col.count("a", 1)
    col.close()
    fault.FaultPlan.reset_active()
    assert not os.path.exists(path)
    assert col.records[0].get("sink_degraded") is True
    assert col.dropped_records == 2          # meta + counter
    assert [r["kind"] for r in col.records] == ["meta", "counter"]


def test_solve_survives_flaky_telemetry_sink(tmp_path):
    """The integration cut: a solve with telemetry on a flaky sink must
    complete normally — degraded observability, untouched results."""
    kern = diffusion_kernel()
    rng = np.random.RandomState(7)
    T, Ci, sc = setup3d(rng)
    clean = iterate.solve_until(kern, {"T": T, "T2": T, "Ci": Ci}, sc,
                                tol=1e-4, max_iters=200, check_every=4)
    path = str(tmp_path / "flaky.jsonl")
    with _FlakyIO() as flaky:
        col = telemetry.configure(path=path)
        flaky.arm(io_errors=3 * col.IO_ATTEMPTS)
        res = iterate.solve_until(kern, {"T": T, "T2": T, "Ci": Ci}, sc,
                                  tol=1e-4, max_iters=200, check_every=4,
                                  telemetry=col)
        col.close()
    np.testing.assert_array_equal(np.asarray(res.fields["T"]),
                                  np.asarray(clean.fields["T"]))
    assert col.dropped_records >= 1
    telemetry.reset()


# ------------------------------------------------------------- rank merge
def _write_rank_stream(path, spans, rank=None):
    """Hand-rolled per-rank JSONL with controlled timestamps."""
    with open(path, "w") as f:
        for ts, name, dur in spans:
            rec = {"kind": "span", "ts": ts, "name": name, "dur_s": dur}
            if rank is not None:
                rec["rank"] = rank
            f.write(json.dumps(rec) + "\n")


def test_merge_records_interleaves_by_timestamp(tmp_path):
    # rank 0's records are rank-stamped; rank 1's rely on the
    # rank_<i> filename fallback
    p0 = str(tmp_path / "rank_0.jsonl")
    p1 = str(tmp_path / "rank_1.jsonl")
    _write_rank_stream(p0, [(1.0, "solve.chunk", 0.5),
                            (3.0, "solve.chunk", 0.7)], rank=0)
    _write_rank_stream(p1, [(2.0, "solve.chunk", 0.6),
                            (4.0, "exchange", 0.1)])
    merged = report.merge_records([p0, p1])
    assert [r["ts"] for r in merged] == [1.0, 2.0, 3.0, 4.0]
    assert [r["rank"] for r in merged] == [0, 1, 0, 1]

    rows = report.per_rank_phase_summary(merged)
    assert {(r["phase"], r["rank"], r["count"]) for r in rows} == {
        ("solve.chunk", 0, 2), ("solve.chunk", 1, 1), ("exchange", 1, 1)}


def test_report_cli_merge_glob(tmp_path, capsys):
    p0 = str(tmp_path / "rank_0.jsonl")
    p1 = str(tmp_path / "rank_1.jsonl")
    _write_rank_stream(p0, [(1.0, "solve.chunk", 0.5)], rank=0)
    _write_rank_stream(p1, [(2.0, "solve.chunk", 0.9)], rank=1)
    assert report.main(["--merge", str(tmp_path / "rank_*.jsonl")]) == 0
    out = capsys.readouterr().out
    assert "Per-rank phases" in out
    assert "ranks: [0, 1]" in out or "rank" in out
    # one row per (phase, rank): the straggling rank is visible as its
    # own 0.9 s row, not averaged into the other rank's 0.5 s
    assert "0.9" in out and "0.5" in out


def test_report_cli_merge_no_match_notice(tmp_path, capsys):
    lone = str(tmp_path / "run.jsonl")
    _write_rank_stream(lone, [(1.0, "solve.chunk", 0.5)])
    rc = report.main([lone, "--merge", str(tmp_path / "nope_*.jsonl")])
    assert rc == 0
    err = capsys.readouterr().err
    assert "no files match" in err


def test_collector_rank_stamp(tmp_path):
    path = str(tmp_path / "rank_3.jsonl")
    col = telemetry.configure_rank(3, path=path)
    col.count("steps", 2)
    with col.span("solve.chunk"):
        pass
    col.close()
    recs = schema.load_records(path)
    assert recs and all(r.get("rank") == 3 for r in recs)
    telemetry.reset()
