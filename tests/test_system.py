"""End-to-end behaviour tests: the full train->checkpoint->resume->serve
path through the public API (the launcher the dry-run compiles)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import TrainLoopConfig, train
from repro.launch.serve import ServeConfig, serve


def test_train_loss_decreases_and_resumes(tmp_path):
    loop = TrainLoopConfig(steps=16, seq_len=64, global_batch=4,
                           ckpt_dir=str(tmp_path), ckpt_every=8, log_every=50)
    _, _, hist = train("mamba2-130m", loop, smoke=True, log_fn=lambda *_: None)
    assert len(hist) == 16
    assert hist[-1] < hist[0], (hist[0], hist[-1])
    assert all(np.isfinite(h) for h in hist)

    # resume continues from the checkpoint, not from scratch
    loop2 = TrainLoopConfig(steps=20, seq_len=64, global_batch=4,
                            ckpt_dir=str(tmp_path), resume=True,
                            ckpt_every=50, log_every=50)
    _, _, hist2 = train("mamba2-130m", loop2, smoke=True, log_fn=lambda *_: None)
    assert len(hist2) == 4  # steps 16..19 only
    assert hist2[0] < hist[0]  # warm start


def test_resume_bitwise_matches_uninterrupted(tmp_path):
    """Fault-tolerance contract: crash+restore reproduces the exact same
    trajectory as the uninterrupted run (deterministic data + exact state).
    Constant LR schedule so the horizon doesn't differ between the
    interrupted and full runs."""
    from repro.models import RunConfig
    rc = lambda: RunConfig(param_dtype="float32", remat=False, loss_chunk=32,
                           schedule="const", warmup_steps=1)
    kw = dict(seq_len=32, global_batch=2)
    loop_a = TrainLoopConfig(steps=10, ckpt_dir=str(tmp_path / "a"),
                             ckpt_every=100, log_every=100, **kw)
    _, _, hist_a = train("stablelm-3b", loop_a, rc=rc(), smoke=True,
                         log_fn=lambda *_: None)
    loop_b1 = TrainLoopConfig(steps=5, ckpt_dir=str(tmp_path / "b"),
                              ckpt_every=5, log_every=100, **kw)
    train("stablelm-3b", loop_b1, rc=rc(), smoke=True, log_fn=lambda *_: None)
    loop_b2 = TrainLoopConfig(steps=10, ckpt_dir=str(tmp_path / "b"),
                              resume=True, ckpt_every=100, log_every=100, **kw)
    _, _, hist_b = train("stablelm-3b", loop_b2, rc=rc(), smoke=True,
                         log_fn=lambda *_: None)
    np.testing.assert_allclose(hist_a[5:], hist_b, rtol=1e-5)


def test_serve_generates(tmp_path):
    gen, stats = serve("mamba2-130m",
                       ServeConfig(batch=2, prompt_len=12, gen_len=6,
                                   temperature=0.0),
                       smoke=True, log_fn=lambda *_: None)
    assert gen.shape == (2, 6)
    assert stats["tok_per_s"] > 0
    # greedy decode is deterministic
    gen2, _ = serve("mamba2-130m",
                    ServeConfig(batch=2, prompt_len=12, gen_len=6,
                                temperature=0.0),
                    smoke=True, log_fn=lambda *_: None)
    np.testing.assert_array_equal(gen, gen2)
