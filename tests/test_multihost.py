"""True multi-process operation: rendezvous bounds, run-id heartbeats,
the gang supervisor's kill/replan/resume loop, and the multi-process
serving pool.

Every ``@pytest.mark.multihost`` test here launches REAL OS processes
joined by ``jax.distributed`` over gloo CPU collectives — no simulated
devices on these paths. The acceptance scenario: a 4-process
``elastic_solve_until`` loses one rank to SIGKILL mid-solve; the
supervisor detects the exit, terminates the wedged stragglers, re-plans
the world to the largest grid-compatible size (4 -> 2, because 3 does
not divide the interior) and resumes from the last global checkpoint —
allclose to the uninterrupted 1-process reference.
"""
import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.distributed import elastic, fault
from repro.launch import multihost

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_child(code: str, env_extra: dict | None = None,
              timeout: int = 180) -> subprocess.CompletedProcess:
    """One real single-device process (no fake-device XLA flags)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = ""
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC
    env.pop(fault.PLAN_ENV, None)
    if env_extra:
        env.update(env_extra)
    return subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=timeout, env=env)


# ---------------------------------------------------------------------------
# run-id heartbeat namespacing + stale retirement (satellite 1)
# ---------------------------------------------------------------------------
def test_heartbeat_run_id_namespacing(tmp_path):
    d = str(tmp_path)
    old = fault.Heartbeat(d, rank=0, run_id="dead-run")
    old.bump(99)
    new = fault.Heartbeat(d, rank=1, run_id="live-run")
    new.bump(5)
    # a fresh run NEVER reads the previous run's liveness
    assert list(new.read_all()) == [1]
    assert old.read_all()[0]["run_id"] == "dead-run"
    # legacy un-namespaced heartbeats are invisible to namespaced readers
    fault.Heartbeat(d, rank=2).bump(1)
    assert list(new.read_all()) == [1]


def test_heartbeat_retire_stale(tmp_path):
    d = str(tmp_path)
    fault.Heartbeat(d, rank=0, run_id="a").bump(1)
    fault.Heartbeat(d, rank=1, run_id="b").bump(1)
    fault.Heartbeat(d, rank=2).bump(1)          # legacy, no namespace
    retired = fault.Heartbeat.retire_stale(d, keep_run_id="b")
    assert retired == ["a.host_0.json", "host_2.json"]
    assert os.path.exists(os.path.join(d, "b.host_1.json"))
    # retire everything: a fresh launcher start
    assert fault.Heartbeat.retire_stale(d) == ["b.host_1.json"]
    assert fault.Heartbeat.retire_stale(d) == []


def test_dead_rank_detection_ignores_foreign_run(tmp_path):
    d = str(tmp_path)
    fault.Heartbeat(d, rank=0, run_id="old").bump(1)   # fresh file, old run
    hb = fault.Heartbeat(d, rank=1, run_id="new", timeout_s=10.0)
    hb.bump(1)
    # rank 0 of THIS run never beat: dead despite the old run's file
    assert hb.dead_ranks(expected=[0, 1]) == [0]


def test_monitor_run_id_passthrough(tmp_path):
    mon = fault.StepMonitor(host_id=3, heartbeat_dir=str(tmp_path),
                            run_id="r7", timeout_s=5.0)
    mon.record(1, 0.01)
    assert os.path.exists(os.path.join(str(tmp_path), "r7.host_3.json"))
    assert mon.check_peers()["dead"] == []


# ---------------------------------------------------------------------------
# rendezvous failure modes are BOUNDED (never hang)
# ---------------------------------------------------------------------------
_RDV_CHILD = r"""
import sys
from repro.launch import multihost
try:
    multihost.initialize(coordinator={coord!r}, num_processes=2,
                         process_id={rank}, timeout_s={timeout},
                         attempts={attempts})
except multihost.RendezvousError as e:
    print("RENDEZVOUS_ERROR:", e)
    sys.exit(7)
print("JOINED")
"""


@pytest.mark.multihost
def test_rendezvous_coordinator_down_is_pointed_not_a_hang():
    # nothing listens on this port: the non-coordinator rank must fail
    # with a pointed error within its bounded budget
    port = multihost.free_port()
    t0 = time.monotonic()
    p = run_child(_RDV_CHILD.format(coord=f"127.0.0.1:{port}", rank=1,
                                    timeout=5, attempts=2), timeout=120)
    took = time.monotonic() - t0
    assert p.returncode == 7, (p.stdout, p.stderr)
    assert "RENDEZVOUS_ERROR" in p.stdout
    assert "coordinator" in p.stdout and "127.0.0.1" in p.stdout
    assert took < 90, f"rendezvous failure took {took:.0f}s — not bounded"


@pytest.mark.multihost
def test_rendezvous_slow_joiner_is_time_bounded():
    # rank 0 brings up the coordinator and waits for a rank 1 that never
    # arrives. XLA's distributed client terminates the process with
    # LOG(FATAL) on the register deadline — no Python exception to
    # convert — so the contract here is a TIME-BOUNDED death that the
    # Supervisor turns into a replan/restart (see the mid-init test)
    t0 = time.monotonic()
    p = run_child(_RDV_CHILD.format(coord=multihost.default_coordinator(),
                                    rank=0, timeout=5, attempts=1),
                  timeout=120)
    took = time.monotonic() - t0
    assert p.returncode != 0
    assert "JOINED" not in p.stdout
    assert "DEADLINE_EXCEEDED" in p.stderr or "Deadline Exceeded" in p.stderr
    assert took < 90, f"slow-joiner wait took {took:.0f}s — not bounded"


def test_initialize_single_process_shortcut_and_config_errors():
    ctx = multihost.initialize()          # no world configured: a no-op
    assert (ctx.rank, ctx.world) == (0, 1)
    with pytest.raises(multihost.RendezvousError, match="incomplete"):
        multihost.initialize(coordinator="127.0.0.1:1", num_processes=4)


# ---------------------------------------------------------------------------
# THE acceptance scenario: 4 real processes, SIGKILL one, replan, resume
# ---------------------------------------------------------------------------
@pytest.mark.multihost
@pytest.mark.distributed
def test_four_process_kill_replan_resume_allclose(tmp_path):
    work = str(tmp_path / "gang")
    sup = multihost.demo_supervisor(
        4, work, kill_rank=1, kill_at=20, heartbeat_timeout_s=30.0,
        attempt_deadline_s=150.0, run_id="accept", verbose=False)
    out = sup.run()

    # one planned death, one restart, world re-planned 4 -> 2 (3 does
    # not divide the interior-16 grid)
    assert out.exit_codes[0] == fault.KILL_EXIT_CODE
    assert out.exit_codes[-1] == 0
    assert out.restarts == 1
    assert out.final_world == 2
    assert out.reports[0].exit_codes[1] == fault.KILL_EXIT_CODE
    assert "rank(s) [1] exited" in out.reports[0].reason

    # attempt 1 resumed from the last global checkpoint, not iteration 0
    log0 = os.path.join(work, "hb", "accept-a1.rank0.log")
    with open(log0) as f:
        tail = f.read()
    assert "resumed_from=20" in tail, tail

    # uninterrupted 1-process reference: allclose (cross-mesh contract)
    ref_work = str(tmp_path / "ref")
    ref = multihost.demo_supervisor(1, ref_work, run_id="ref",
                                    verbose=False).run()
    assert ref.exit_codes == [0]
    np.testing.assert_allclose(
        np.load(os.path.join(work, "out.npy")),
        np.load(os.path.join(ref_work, "out.npy")), atol=1e-5)


@pytest.mark.multihost
@pytest.mark.distributed
def test_mid_init_death_triggers_supervised_restart(tmp_path):
    # rank 1 dies ENTERING the rendezvous; rank 0's init times out; the
    # supervisor catches the planned exit, replans to 1 and completes —
    # all within the configured bounds
    work = str(tmp_path / "gang")
    sup = multihost.demo_supervisor(
        2, work, kill_rank=1, kill_at_rendezvous=1,
        rendezvous_timeout_s=10.0, attempt_deadline_s=120.0,
        run_id="midinit", verbose=False)
    t0 = time.monotonic()
    out = sup.run()
    took = time.monotonic() - t0
    assert out.exit_codes[0] == fault.KILL_EXIT_CODE
    assert out.exit_codes[-1] == 0
    assert out.final_world == 1
    assert took < 150, f"supervised restart took {took:.0f}s"
    assert os.path.exists(os.path.join(work, "out.npy"))


def test_supervisor_replan_respects_divisibility():
    # interior 16 (n=18, r=1): 4 -> 2, never 3
    assert elastic.plan_compatible((18, 18, 18), 1, 3) == (2, (2,))
    assert elastic.plan_compatible((18, 18, 18), 1, 4) == (4, (4,))
    with pytest.raises(ValueError, match="thinner than one ghost ring"):
        elastic.plan_compatible((3, 3, 3), 2, 4)
    with pytest.raises(ValueError, match="largest compatible world"):
        multihost.demo_supervisor(3, "/tmp/never-used")


# ---------------------------------------------------------------------------
# multi-process serving pool: worker death recovers claims, loses nothing
# ---------------------------------------------------------------------------
@pytest.mark.multihost
@pytest.mark.distributed
def test_process_pool_survives_worker_kills(tmp_path):
    from repro.core import iterate
    from repro.serve.pool import ProcessWorkerPool
    from repro.serve.procworker import demo_kernel

    n = 10
    rng = np.random.RandomState(3)
    inits = [np.asarray(rng.rand(n, n, n), np.float32) for _ in range(4)]

    # every first-generation worker dies after ONE served request; the
    # pool must recover the claims and respawn until all four resolve
    plan = fault.FaultPlan(kill_worker_after=1)
    pool = ProcessWorkerPool(
        str(tmp_path / "spool"), workers=2, heartbeat_timeout_s=60.0,
        max_worker_restarts=4, env={fault.PLAN_ENV: plan.to_env()})
    with pool:
        tickets = [pool.submit({"T2": a, "T": a}, {"dt": 1e-3},
                               tol=0.0, max_iters=8, check_every=4)
                   for a in inits]
        results = [t.result(timeout=150.0) for t in tickets]
    assert pool.restarts >= 1

    kern = demo_kernel()
    for a, (fields, meta) in zip(inits, results):
        ref = iterate.solve_until(kern, {"T2": a, "T": a}, {"dt": 1e-3},
                                  tol=0.0, max_iters=8, check_every=4)
        assert meta["iters"] == 8
        np.testing.assert_allclose(fields["T"], np.asarray(ref.fields["T"]),
                                   atol=1e-6)


@pytest.mark.multihost
@pytest.mark.distributed
def test_process_pool_recovers_wedged_worker_without_kill_loop(tmp_path):
    """The stale-heartbeat recovery path (not exit codes): a worker that
    WEDGES — alive but never bumping again — is SIGKILLed and its
    heartbeat file retired before the respawn. The replacement needs
    seconds of startup before its first bump; the dead incarnation's
    leftover file (still older than the timeout) must not condemn it,
    or the watcher kill-loops replacements until the restart budget is
    gone and the backlog is abandoned."""
    from repro.core import iterate
    from repro.serve.pool import ProcessWorkerPool
    from repro.serve.procworker import demo_kernel

    n = 10
    rng = np.random.RandomState(7)
    inits = [np.asarray(rng.rand(n, n, n), np.float32) for _ in range(3)]

    # the single first-generation worker serves ONE request, then wedges
    plan = fault.FaultPlan(wedge_worker_after=1)
    pool = ProcessWorkerPool(
        str(tmp_path / "spool"), workers=1, heartbeat_timeout_s=10.0,
        max_worker_restarts=2, env={fault.PLAN_ENV: plan.to_env()})
    with pool:
        tickets = [pool.submit({"T2": a, "T": a}, {"dt": 1e-3},
                               tol=0.0, max_iters=8, check_every=4)
                   for a in inits]
        results = [t.result(timeout=150.0) for t in tickets]
    assert pool.restarts >= 1
    assert not pool.failed, "replacement was kill-looped by the stale file"

    kern = demo_kernel()
    for a, (fields, meta) in zip(inits, results):
        ref = iterate.solve_until(kern, {"T2": a, "T": a}, {"dt": 1e-3},
                                  tol=0.0, max_iters=8, check_every=4)
        assert meta["iters"] == 8
        np.testing.assert_allclose(fields["T"], np.asarray(ref.fields["T"]),
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# launcher CLI (the README runbook path)
# ---------------------------------------------------------------------------
@pytest.mark.multihost
@pytest.mark.distributed
def test_cli_demo_smoke(tmp_path):
    env = dict(os.environ)
    env["XLA_FLAGS"] = ""
    env["PYTHONPATH"] = SRC
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.multihost", "--demo",
         "--world", "2", "--workdir", str(tmp_path / "w"),
         "--max-iters", "8", "--deadline", "120"],
        capture_output=True, text=True, timeout=180, env=env)
    assert p.returncode == 0, (p.stdout, p.stderr)
    lines = p.stdout.splitlines()
    report = json.loads("\n".join(lines[lines.index("{"):]))
    assert report["restarts"] == 0 and report["final_world"] == 2
