"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import boundary, fd2d, fd3d, init_parallel_stencil
from repro.distributed import compression
from repro.data import DataConfig, make_source
from repro.kernels import ops, ref

SETTINGS = dict(max_examples=20, deadline=None)


@given(nx=st.integers(6, 24), ny=st.integers(6, 24), nz=st.integers(6, 20),
       seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_pallas_stencil_matches_jnp_any_shape(nx, ny, nz, seed):
    """The Pallas backend equals the jnp backend for arbitrary shapes
    (launch derivation must handle awkward extents)."""
    rng = np.random.RandomState(seed)
    T = jnp.asarray(rng.rand(nx, ny, nz), jnp.float32)
    Ci = jnp.asarray(rng.rand(nx, ny, nz) + 0.5, jnp.float32)

    def kern(T2, T, Ci, dt):
        return {"T2": fd3d.inn(T) + dt * fd3d.inn(Ci) * (
            fd3d.d2_xi(T) + fd3d.d2_yi(T) + fd3d.d2_zi(T))}

    outs = []
    for backend in ("jnp", "pallas"):
        ps = init_parallel_stencil(backend=backend, ndims=3)
        k = ps.parallel(outputs=("T2",))(kern)
        outs.append(np.asarray(k(T2=T, T=T, Ci=Ci, dt=1e-3)))
    np.testing.assert_allclose(outs[0], outs[1], atol=5e-6)


@given(seed=st.integers(0, 2**31 - 1), n=st.integers(8, 32))
@settings(**SETTINGS)
def test_diffusion_max_principle(seed, n):
    """Explicit diffusion under the stability bound never creates new
    extrema (discrete maximum principle)."""
    rng = np.random.RandomState(seed)
    T = jnp.asarray(rng.rand(n, n, n), jnp.float32)
    inv = float(n - 1)
    dt = 1.0 / (inv ** 2) / 6.1  # paper's bound with lam/Ci = 1
    out = ref.diffusion3d_step(T, T, jnp.ones_like(T), 1.0, dt, inv, inv, inv)
    assert float(out.max()) <= float(T.max()) + 1e-6
    assert float(out.min()) >= float(T.min()) - 1e-6


@given(nx=st.integers(4, 24), ny=st.integers(4, 24), i=st.integers(0, 4),
       w=st.integers(3, 8), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_staggered_operator_shape_and_window_contract(nx, ny, i, w, seed):
    """The staggered operators d_xa/av_xa (and y-analogues) must (a) shave
    exactly one point off the differentiated axis and nothing else, and
    (b) commute with window extraction — evaluating on a sub-window equals
    slicing the full-array result. (b) is the contract that lets one
    kernel source run on full arrays AND halo-extended Pallas windows."""
    rng = np.random.RandomState(seed)
    A = jnp.asarray(rng.randn(nx, ny), jnp.float32)
    assert fd2d.d_xa(A).shape == (nx - 1, ny)
    assert fd2d.av_xa(A).shape == (nx - 1, ny)
    assert fd2d.d_ya(A).shape == (nx, ny - 1)
    assert fd2d.av_ya(A).shape == (nx, ny - 1)
    np.testing.assert_allclose(np.asarray(fd2d.d_xa(A)),
                               np.diff(np.asarray(A), axis=0), rtol=1e-6)
    # window contract along the staggered axis
    lo = min(i, nx - 3)
    hi = min(lo + w, nx)
    win = A[lo:hi, :]
    np.testing.assert_array_equal(np.asarray(fd2d.d_xa(win)),
                                  np.asarray(fd2d.d_xa(A))[lo:hi - 1, :])
    np.testing.assert_array_equal(np.asarray(fd2d.av_xa(win)),
                                  np.asarray(fd2d.av_xa(A))[lo:hi - 1, :])


@given(nx=st.integers(6, 20), ny=st.integers(6, 24),
       seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_pallas_staggered_fields_match_jnp_any_shape(nx, ny, seed):
    """Mixed-shape coupled systems (cell scalars + face-centered fluxes)
    agree between the jnp backend and pallas windows for arbitrary extents:
    staggered `@all`-write outputs and staggered inputs both round-trip."""
    rng = np.random.RandomState(seed)
    phi = jnp.asarray(rng.rand(nx, ny), jnp.float32)
    Pe = jnp.asarray(rng.rand(nx, ny), jnp.float32)
    qx0 = jnp.zeros((nx - 1, ny), jnp.float32)
    qy0 = jnp.zeros((nx, ny - 1), jnp.float32)

    def flux(qx, qy, phi, Pe):
        k = (phi + 0.5) ** 2
        return {"qx": -fd2d.av_xa(k) * fd2d.d_xa(Pe),
                "qy": -fd2d.av_ya(k) * (fd2d.d_ya(Pe) - fd2d.av_ya(phi))}

    def upd(phi2, phi, Pe, qx, qy, dt):
        div_q = fd2d.d_xa(qx[:, 1:-1]) + fd2d.d_ya(qy[1:-1, :])
        return {"phi2": fd2d.inn(phi) - dt * (div_q + fd2d.inn(Pe))}

    outs = []
    for backend in ("jnp", "pallas"):
        ps = init_parallel_stencil(backend=backend, ndims=2)
        q = ps.parallel(outputs=("qx", "qy"))(flux)(
            qx=qx0, qy=qy0, phi=phi, Pe=Pe)
        phi2 = ps.parallel(outputs=("phi2",))(upd)(
            phi2=phi, phi=phi, Pe=Pe, qx=q["qx"], qy=q["qy"], dt=1e-2)
        outs.append((np.asarray(q["qx"]), np.asarray(q["qy"]),
                     np.asarray(phi2)))
    for a, b in zip(outs[0], outs[1]):
        np.testing.assert_allclose(a, b, atol=5e-6)


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_periodic_diffusion_conserves_mass(seed):
    """With periodic ghost layers, one interior update conserves the total
    heat of the periodic cell (sum over interior)."""
    rng = np.random.RandomState(seed)
    n = 16
    T = jnp.asarray(rng.rand(n, n), jnp.float32)
    T = boundary.periodic(T)
    dt = 1e-2 / 4.0

    def kern(T2, T, dt):
        return {"T2": fd2d.inn(T) + dt * (fd2d.d2_xi(T) + fd2d.d2_yi(T))}

    ps = init_parallel_stencil(backend="jnp", ndims=2)
    out = ps.parallel(outputs=("T2",))(kern)(T2=T, T=T, dt=dt)
    before = float(jnp.sum(T[1:-1, 1:-1]))
    after = float(jnp.sum(out[1:-1, 1:-1]))
    assert abs(after - before) < 1e-3 * max(abs(before), 1.0)


@given(seed=st.integers(0, 2**31 - 1),
       B=st.integers(1, 3), L=st.sampled_from([16, 32, 48]),
       window=st.sampled_from([None, 8, 24]))
@settings(**SETTINGS)
def test_chunked_attention_property(seed, B, L, window):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(B, 4, L, 8), jnp.float32)
    k = jnp.asarray(rng.randn(B, 2, L, 8), jnp.float32)
    v = jnp.asarray(rng.randn(B, 2, L, 8), jnp.float32)
    want = ref.attention(q, k, v, causal=True, window=window)
    got = ops.attention(q, k, v, causal=True, window=window, impl="chunked",
                        q_chunk=16, k_chunk=16)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-4)


@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 1e3))
@settings(**SETTINGS)
def test_int8_quantization_error_bound(seed, scale):
    """Error of symmetric per-block int8 quantization is <= scale/254 per
    element (half a quantization step of the block's max)."""
    rng = np.random.RandomState(seed)
    g = jnp.asarray(rng.randn(1000) * scale, jnp.float32)
    q, s, meta = compression.quantize_int8(g)
    back = compression.dequantize_int8(q, s, meta)
    bound = float(jnp.max(jnp.abs(g))) / 254 + 1e-8
    assert float(jnp.max(jnp.abs(back - g))) <= bound * 1.01


@given(step=st.integers(0, 1000), shards=st.sampled_from([1, 2, 4]),
       seed=st.integers(0, 10000))
@settings(**SETTINGS)
def test_data_shards_partition_global_batch(step, shards, seed):
    """Shard batches are disjoint slices of one deterministic global batch:
    re-running any (step, shard) reproduces identical data — the failover
    recovery contract."""
    gb = 8
    batches = []
    for sid in range(shards):
        cfg = DataConfig(vocab=512, seq_len=12, global_batch=gb,
                         n_shards=shards, shard_id=sid, seed=seed)
        src = make_source(cfg)
        b1 = src.batch(step)
        b2 = make_source(cfg).batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        batches.append(b1["tokens"])
    allb = np.concatenate(batches)
    assert allb.shape == (gb, 12)
    assert (allb >= 0).all() and (allb < 512).all()


@given(seed=st.integers(0, 2**31 - 1), L=st.sampled_from([16, 31, 64]))
@settings(**SETTINGS)
def test_conv1d_property(seed, L):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(2, L, 8), jnp.float32)
    w = jnp.asarray(rng.randn(4, 8), jnp.float32)
    want = ref.conv1d_causal(x, w)
    got = ops.conv1d_causal(x, w, impl="pallas")
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)
    # causality: perturbing x[t0] never changes out[:, :t0]
    t0 = L // 2
    x2 = x.at[:, t0].add(1.0)
    got2 = ops.conv1d_causal(x2, w, impl="pallas")
    np.testing.assert_array_equal(np.asarray(got[:, :t0]),
                                  np.asarray(got2[:, :t0]))
