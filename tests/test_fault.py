"""Fault tolerance: retry/backoff, deterministic fault injection,
durable checkpoints, kill/resume, and elastic restart on a reshaped mesh.

Comparison contract (same as test_reductions): a killed single-device
run resumed on the SAME machine replays the identical compiled program
from the checkpointed carry, so it is compared BITWISE against the
uninterrupted run. A resume on a *different* mesh re-decomposes the
global arrays and the rank-combined reductions reassociate — those
comparisons are allclose, never equality.

Process-death tests run real subprocesses: ``REPRO_FAULT_PLAN`` makes
an unmodified ``solve_until`` die via ``os._exit(113)`` at an exact
iteration count, the parent asserts the planned exit code, and a second
launch resumes from the atomic checkpoint.
"""
import json
import os
import subprocess
import sys
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointError, CheckpointManager
from repro.core import fd3d, init_parallel_stencil, iterate
from repro.distributed import fault, overlap

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def run_proc(code: str, n_devices: int = 1, env_extra: dict | None = None,
             timeout: int = 560) -> subprocess.CompletedProcess:
    """Like conftest.run_subprocess but returns the CompletedProcess so
    kill-injection tests can assert a NONZERO planned exit code."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop(fault.PLAN_ENV, None)
    if env_extra:
        env.update(env_extra)
    return subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=timeout, env=env)


@pytest.fixture()
def active_plan(monkeypatch):
    """Install a FaultPlan as the process-wide active plan; restores the
    no-plan state afterwards."""
    def install(plan: fault.FaultPlan):
        monkeypatch.setenv(fault.PLAN_ENV, plan.to_env())
        fault.FaultPlan.reset_active()
        return fault.FaultPlan.active()
    yield install
    fault.FaultPlan.reset_active()


def diffusion_kernel():
    ps = init_parallel_stencil(backend="jnp", ndims=3)

    @ps.parallel(outputs=("T2",), rotations={"T2": "T"},
                 reductions={"err": "max_abs_diff(T2, T)"})
    def kern(T2, T, dt):
        return {"T2": fd3d.inn(T) + dt * (fd3d.d2_xi(T) + fd3d.d2_yi(T)
                                          + fd3d.d2_zi(T))}

    return kern


def spike(n=16):
    return jnp.zeros((n, n, n), jnp.float32).at[n // 2, n // 2, n // 2].set(1.0)


# ---------------------------------------------------------------------------
# retry with backoff + jitter
# ---------------------------------------------------------------------------
def test_retry_backoff_schedule_and_jitter_bounds():
    waits: list[float] = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 4:
            raise OSError("transient")
        return "ok"

    out = fault.retry(flaky, attempts=4, backoff_s=0.1, max_backoff_s=0.3,
                      jitter=0.25, seed=7, sleep=waits.append)
    assert out == "ok" and calls["n"] == 4
    assert len(waits) == 3
    for i, w in enumerate(waits):
        nominal = min(0.1 * 2 ** i, 0.3)
        assert nominal * 0.75 <= w <= nominal * 1.25, (i, w, nominal)


def test_retry_jitter_deterministic_with_seed():
    def seq(seed):
        waits = []
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 4:
                raise OSError()
            return 1

        fault.retry(flaky, attempts=4, backoff_s=0.05, jitter=0.5,
                    seed=seed, sleep=waits.append)
        return waits

    assert seq(3) == seq(3)
    assert seq(3) != seq(4)


def test_retry_exhausts_and_propagates():
    waits = []
    with pytest.raises(OSError, match="persistent"):
        fault.retry(lambda: (_ for _ in ()).throw(OSError("persistent")),
                    attempts=3, backoff_s=0.01, sleep=waits.append)
    assert len(waits) == 2  # no sleep after the final attempt


def test_retry_does_not_catch_unlisted_exceptions():
    with pytest.raises(KeyError):
        fault.retry(lambda: {}["missing"], attempts=4, sleep=lambda s: None)


# ---------------------------------------------------------------------------
# FaultPlan parsing + hooks
# ---------------------------------------------------------------------------
def test_fault_plan_env_roundtrip():
    plan = fault.FaultPlan(kill_at_step=60, io_errors=2)
    again = fault.FaultPlan.from_env({fault.PLAN_ENV: plan.to_env()})
    assert again.kill_at_step == 60 and again.io_errors == 2
    assert fault.FaultPlan.from_env({}) is None


def test_fault_plan_rejects_bad_env():
    with pytest.raises(ValueError, match="unknown keys"):
        fault.FaultPlan.from_env({fault.PLAN_ENV: '{"kill_at": 3}'})
    with pytest.raises(ValueError, match="not valid JSON"):
        fault.FaultPlan.from_env({fault.PLAN_ENV: "{nope"})
    with pytest.raises(ValueError, match="JSON object"):
        fault.FaultPlan.from_env({fault.PLAN_ENV: "[1, 2]"})


def test_fault_plan_io_budget():
    plan = fault.FaultPlan(io_errors=2)
    with pytest.raises(fault.TransientIOError):
        plan.on_io("/a")
    with pytest.raises(fault.TransientIOError):
        plan.on_io("/b")
    plan.on_io("/c")  # budget spent: no raise


def test_fault_plan_on_step_respects_rank():
    plan = fault.FaultPlan(hang_at_step=5, hang_s=0.01, rank=1)
    t0 = time.perf_counter()
    plan.on_step(10, rank=0)           # not this plan's rank: no-op
    assert time.perf_counter() - t0 < 0.005
    plan.on_step(10, rank=1)           # hangs once
    assert plan.hang_at_step is None   # consumed


def test_kill_at_step_exits_with_planned_code():
    code = """
from repro.distributed import fault
plan = fault.FaultPlan(kill_at_step=3)
for step in range(10):
    plan.on_step(step)
print("UNREACHABLE")
"""
    p = run_proc(code)
    assert p.returncode == fault.KILL_EXIT_CODE, (p.stdout, p.stderr)
    assert "UNREACHABLE" not in p.stdout


# ---------------------------------------------------------------------------
# heartbeats, stragglers, monitored stepping
# ---------------------------------------------------------------------------
def test_heartbeat_dead_and_straggler_flagging(tmp_path):
    d = str(tmp_path)
    now = time.time()
    # ranks 0/3 healthy, rank 1 = straggler (slow EWMA), rank 2 = dead
    fault.Heartbeat(d, rank=0).bump(100, ewma_s=0.10)
    fault.Heartbeat(d, rank=3).bump(98, ewma_s=0.12)
    fault.Heartbeat(d, rank=1).bump(80, ewma_s=1.0)
    with open(os.path.join(d, "host_2.json"), "w") as f:
        json.dump({"step": 40, "t": now - 1000.0, "ewma_s": 0.1}, f)

    hb = fault.Heartbeat(d, rank=0, timeout_s=300.0)
    assert hb.dead_ranks(now=now) == [2]
    assert hb.dead_ranks(expected=[0, 1, 2, 3, 4], now=now) == [2, 4]

    mon = fault.StepMonitor(host_id=0, heartbeat_dir=d,
                            straggler_factor=1.5, timeout_s=300.0)
    health = mon.check_peers(now=now)
    assert health["dead"] == [2]
    assert health["stragglers"] == [1]


def test_heartbeat_ignores_torn_files(tmp_path):
    d = str(tmp_path)
    fault.Heartbeat(d, rank=0).bump(10)
    with open(os.path.join(d, "host_1.json"), "w") as f:
        f.write('{"step": 5, "t":')  # torn mid-write
    beats = fault.Heartbeat(d).read_all()
    assert list(beats) == [0]


def test_monitored_stepper_raises_rank_failure(tmp_path):
    d = str(tmp_path)
    with open(os.path.join(d, "host_7.json"), "w") as f:
        json.dump({"step": 1, "t": time.time() - 1000.0, "ewma_s": 0.1}, f)
    mon = fault.StepMonitor(host_id=0, heartbeat_dir=d, timeout_s=300.0)
    stepper = overlap.monitored(lambda x: x + 1, mon, check_peers_every=1)
    with pytest.raises(fault.RankFailure) as ei:
        stepper(jnp.float32(1.0))
    assert ei.value.dead == [7]
    # our own heartbeat was still bumped before the check
    assert 0 in fault.Heartbeat(d).read_all()


def test_supervise_replans_world_and_succeeds():
    seen = []

    def attempt(i, world):
        seen.append((i, world))
        return fault.KILL_EXIT_CODE if i < 2 else 0

    attempts, final_world, codes = fault_supervise(attempt, 4)
    assert attempts == 2 and final_world == 2
    assert codes == [fault.KILL_EXIT_CODE, fault.KILL_EXIT_CODE, 0]
    assert seen == [(0, 4), (1, 3), (2, 2)]


def fault_supervise(attempt, world):
    from repro.distributed import elastic
    return elastic.supervise(attempt, world)


def test_supervise_gives_up_after_max_restarts():
    from repro.distributed import elastic
    with pytest.raises(RuntimeError, match="gave up"):
        elastic.supervise(lambda i, w: 1, 4, max_restarts=2)


# ---------------------------------------------------------------------------
# checkpoint durability
# ---------------------------------------------------------------------------
def _tree(v=0.0, n=4):
    return {"fields": {"T": jnp.full((n, n), v, jnp.float32)},
            "err": jnp.float32(v)}


def test_checkpoint_atomic_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30, 40):
        mgr.save(s, _tree(float(s)))
    assert mgr.list_steps() == [30, 40]
    assert mgr.latest_step() == 40
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]
    tree, extra = mgr.restore(_tree())
    assert extra["step"] == 40
    assert float(tree["err"]) == 40.0


def test_keep_k_never_deletes_latest_pointed(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    for s in (10, 20, 30):
        mgr.save(s, _tree(float(s)))
    # crash-recovery state: a newer dir landed but the LATEST swap never
    # happened, so LATEST still names an old step
    with open(os.path.join(tmp_path, "LATEST"), "w") as f:
        f.write("step_%09d" % 10)
    mgr.keep = 1
    mgr._gc()
    # keep=1 would evict 10 and 20 — but LATEST names 10
    assert os.path.isdir(mgr.step_dir(10)), "LATEST-pointed step deleted"
    assert not os.path.isdir(mgr.step_dir(20))
    assert os.path.isdir(mgr.step_dir(30))
    # restore follows the pointer, not the newest dir
    _, extra = mgr.restore(_tree())
    assert extra["step"] == 10


def test_restore_explicit_step_and_shape_validation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(10, _tree(1.0))
    with pytest.raises(FileNotFoundError):
        mgr.restore(_tree(), step=99)
    with pytest.raises(CheckpointError, match="does not match restore"):
        mgr.restore({"fields": {"T": jnp.zeros((8, 8), jnp.float32)},
                     "err": jnp.float32(0)}, step=10)
    with pytest.raises(CheckpointError, match="absent from checkpoint"):
        mgr.restore({"fields": {"Q": jnp.zeros((4, 4), jnp.float32)},
                     "err": jnp.float32(0)}, step=10)


def test_corrupt_checkpoint_falls_back_to_previous(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(10, _tree(1.0))
    mgr.save(20, _tree(2.0))
    # tear the newest step's first tensor (short read on restore)
    d = mgr.step_dir(20)
    victim = sorted(f for f in os.listdir(d) if f.endswith(".npy"))[0]
    path = os.path.join(d, victim)
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) // 2)
    # explicit step: the CheckpointError propagates
    with pytest.raises(CheckpointError):
        mgr.restore(_tree(), step=20)
    # implicit (LATEST): falls back to the previous intact step
    tree, extra = mgr.restore(_tree())
    assert extra["step"] == 10
    assert [s for s, _ in extra["skipped_corrupt"]] == [20]
    assert float(tree["err"]) == 1.0


def test_fault_plan_tears_scheduled_save(tmp_path, active_plan):
    active_plan(fault.FaultPlan(corrupt_checkpoint=2))
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(10, _tree(1.0))
    mgr.save(20, _tree(2.0))   # the torn one
    tree, extra = mgr.restore(_tree())
    assert extra["step"] == 10 and extra["skipped_corrupt"]


def test_transient_io_errors_absorbed_by_retry(tmp_path, active_plan):
    plan = active_plan(fault.FaultPlan(io_errors=3))
    mgr = CheckpointManager(str(tmp_path), keep=3, retry_backoff_s=0.001)
    mgr.save(10, _tree(5.0))   # write path retries through the budget
    assert plan.io_errors == 0
    tree, extra = mgr.restore(_tree())
    assert extra["step"] == 10 and float(tree["err"]) == 5.0


def test_async_save_failure_surfaces_on_wait(tmp_path, monkeypatch):
    mgr = CheckpointManager(str(tmp_path), keep=3, retry_attempts=1)
    monkeypatch.setattr(mgr, "_write",
                        lambda *a, **k: (_ for _ in ()).throw(OSError("disk")))
    mgr.save(10, _tree(), blocking=False)
    with pytest.raises(RuntimeError, match="async checkpoint write failed"):
        mgr.wait()


# ---------------------------------------------------------------------------
# checkpointed solve_until (single device): bitwise contract
# ---------------------------------------------------------------------------
def test_checkpointed_solve_bitwise_equals_plain(tmp_path):
    kern = diffusion_kernel()
    T0 = spike()
    fields = dict(T2=T0, T=T0)
    sc = dict(dt=1e-3)
    plain = iterate.solve_until(kern, fields, sc, tol=1e-6, max_iters=60,
                                check_every=5)
    ck = iterate.Checkpointing(str(tmp_path), save_every=2, blocking=True)
    chunked = iterate.solve_until(kern, fields, sc, tol=1e-6, max_iters=60,
                                  check_every=5, checkpoint=ck)
    assert int(chunked.iters) == int(plain.iters)
    assert float(chunked.err) == float(plain.err)
    for k in fields:
        np.testing.assert_array_equal(np.asarray(chunked.fields[k]),
                                      np.asarray(plain.fields[k]))
    assert chunked.saved_steps, "no checkpoints written"
    assert chunked.resumed_from is None


def test_resume_midway_bitwise_equals_uninterrupted(tmp_path):
    kern = diffusion_kernel()
    T0 = spike()
    fields, sc = dict(T2=T0, T=T0), dict(dt=1e-3)
    full = iterate.solve_until(kern, fields, sc, tol=0.0, max_iters=80,
                               check_every=4)
    ck = iterate.Checkpointing(str(tmp_path), save_every=5, blocking=True)
    part = iterate.solve_until(kern, fields, sc, tol=0.0, max_iters=40,
                               check_every=4, checkpoint=ck)
    assert int(part.iters) == 40
    resumed = iterate.solve_until(kern, fields, sc, tol=0.0, max_iters=80,
                                  check_every=4, checkpoint=ck)
    assert resumed.resumed_from == 40
    assert int(resumed.iters) == 80
    for k in fields:
        np.testing.assert_array_equal(np.asarray(resumed.fields[k]),
                                      np.asarray(full.fields[k]))


def test_solve_with_monitor_raises_on_dead_peer(tmp_path):
    hb_dir = str(tmp_path / "hb")
    os.makedirs(hb_dir)
    with open(os.path.join(hb_dir, "host_3.json"), "w") as f:
        json.dump({"step": 1, "t": time.time() - 1000.0, "ewma_s": 0.1}, f)
    mon = fault.StepMonitor(host_id=0, heartbeat_dir=hb_dir, timeout_s=300.0)
    ck = iterate.Checkpointing(str(tmp_path / "ck"), save_every=1,
                               blocking=True, monitor=mon)
    kern = diffusion_kernel()
    T0 = spike()
    with pytest.raises(fault.RankFailure) as ei:
        iterate.solve_until(kern, dict(T2=T0, T=T0), dict(dt=1e-3),
                            tol=0.0, max_iters=20, check_every=2,
                            checkpoint=ck)
    assert ei.value.dead == [3]


# ---------------------------------------------------------------------------
# process death + resume (real subprocesses, real os._exit)
# ---------------------------------------------------------------------------
_SOLVE_CHILD = r"""
import os, numpy as np, jax.numpy as jnp
from repro.core import fd3d, init_parallel_stencil, iterate

ps = init_parallel_stencil(backend="jnp", ndims=3)

@ps.parallel(outputs=("T2",), rotations={"T2": "T"},
             reductions={"err": "max_abs_diff(T2, T)"})
def kern(T2, T, dt):
    return {"T2": fd3d.inn(T) + dt * (fd3d.d2_xi(T) + fd3d.d2_yi(T)
                                      + fd3d.d2_zi(T))}

n = 16
T0 = jnp.zeros((n, n, n), jnp.float32).at[n//2, n//2, n//2].set(1.0)
ck = iterate.Checkpointing(os.environ["CKPT_DIR"], save_every=2,
                           blocking=True)
res = iterate.solve_until(kern, dict(T2=T0, T=T0), dict(dt=1e-3),
                          tol=0.0, max_iters=60, check_every=5,
                          checkpoint=ck)
np.save(os.environ["OUT_NPY"], np.asarray(res.fields["T"]))
print("DONE", int(res.iters), res.resumed_from)
"""


def test_kill_at_step_then_resume_completes_bitwise(tmp_path):
    ck, out = str(tmp_path / "ck"), str(tmp_path / "out.npy")
    ref = str(tmp_path / "ref.npy")
    env = {"CKPT_DIR": ck, "OUT_NPY": out}

    # attempt 1: the plan kills the process at iteration 30 (a save
    # boundary) -- planned exit code, partial checkpoints on disk
    plan = fault.FaultPlan(kill_at_step=30)
    p = run_proc(_SOLVE_CHILD,
                 env_extra=dict(env, **{fault.PLAN_ENV: plan.to_env()}))
    assert p.returncode == fault.KILL_EXIT_CODE, (p.stdout, p.stderr)
    assert not os.path.exists(out)
    mgr = CheckpointManager(ck)
    assert mgr.latest_step() == 30

    # attempt 2 (no plan): resumes from step 30 and completes
    p = run_proc(_SOLVE_CHILD, env_extra=env)
    assert p.returncode == 0, (p.stdout, p.stderr)
    assert "DONE 60 30" in p.stdout

    # reference: uninterrupted run in a fresh process
    p = run_proc(_SOLVE_CHILD,
                 env_extra={"CKPT_DIR": str(tmp_path / "ck_ref"),
                            "OUT_NPY": ref})
    assert p.returncode == 0, (p.stdout, p.stderr)
    np.testing.assert_array_equal(np.load(out), np.load(ref))


# ---------------------------------------------------------------------------
# elastic: kill on one mesh, resume on another (allclose contract)
# ---------------------------------------------------------------------------
_ELASTIC_CHILD = r"""
import os, numpy as np, jax, jax.numpy as jnp
from repro.core import fd3d, init_parallel_stencil, iterate
from repro.distributed import elastic

ps = init_parallel_stencil(backend="jnp", ndims=3)

@ps.parallel(outputs=("T2",), rotations={"T2": "T"},
             reductions={"err": "max_abs_diff(T2, T)"})
def kern(T2, T, dt):
    return {"T2": fd3d.inn(T) + dt * (fd3d.d2_xi(T) + fd3d.d2_yi(T)
                                      + fd3d.d2_zi(T))}

n = 18  # interior 16: divides over 1, 2 and 4 ranks (radius 1)
rng = np.random.RandomState(0)
T0 = np.asarray(rng.rand(n, n, n), np.float32)
factors = (int(os.environ["FACTOR"]),)
ck = iterate.Checkpointing(os.environ["CKPT_DIR"], save_every=1,
                           blocking=True)
res = elastic.elastic_solve_until(
    kern, dict(T2=T0, T=T0), dict(dt=1e-3), factors=factors,
    tol=0.0, max_iters=40, exchange=("T",), check_every=4,
    checkpoint=ck)
np.save(os.environ["OUT_NPY"], np.asarray(res.fields["T"]))
print("DONE", int(res.iters), res.resumed_from)
"""


@pytest.mark.distributed
def test_elastic_kill_then_resume_on_shrunk_mesh(tmp_path):
    ck = str(tmp_path / "ck")
    out4, out_ref = str(tmp_path / "o4.npy"), str(tmp_path / "ref.npy")

    # 4-rank run dies at iteration 20 (after the save at 20)
    plan = fault.FaultPlan(kill_at_step=20)
    p = run_proc(_ELASTIC_CHILD, n_devices=4,
                 env_extra={"FACTOR": "4", "CKPT_DIR": ck, "OUT_NPY": out4,
                            fault.PLAN_ENV: plan.to_env()})
    assert p.returncode == fault.KILL_EXIT_CODE, (p.stdout, p.stderr)
    assert CheckpointManager(ck).latest_step() == 20

    # survivors: 2-rank mesh resumes the 4-rank checkpoint to completion
    p = run_proc(_ELASTIC_CHILD, n_devices=2,
                 env_extra={"FACTOR": "2", "CKPT_DIR": ck, "OUT_NPY": out4})
    assert p.returncode == 0, (p.stdout, p.stderr)
    assert "DONE 40 20" in p.stdout

    # reference: uninterrupted single-rank run; cross-mesh => allclose
    p = run_proc(_ELASTIC_CHILD, n_devices=1,
                 env_extra={"FACTOR": "1", "CKPT_DIR": str(tmp_path / "cr"),
                            "OUT_NPY": out_ref})
    assert p.returncode == 0, (p.stdout, p.stderr)
    np.testing.assert_allclose(np.load(out4), np.load(out_ref), atol=1e-5)


@pytest.mark.distributed
def test_elastic_resume_on_grown_mesh(tmp_path):
    ck = str(tmp_path / "ck")
    out, out_ref = str(tmp_path / "o.npy"), str(tmp_path / "ref.npy")

    # write a mid-run checkpoint on 2 ranks (capped run, no kill) ...
    code_half = _ELASTIC_CHILD.replace("max_iters=40", "max_iters=20")
    p = run_proc(code_half, n_devices=2,
                 env_extra={"FACTOR": "2", "CKPT_DIR": ck, "OUT_NPY": out})
    assert p.returncode == 0, (p.stdout, p.stderr)

    # ... scale UP: 4 ranks resume it to completion
    p = run_proc(_ELASTIC_CHILD, n_devices=4,
                 env_extra={"FACTOR": "4", "CKPT_DIR": ck, "OUT_NPY": out})
    assert p.returncode == 0, (p.stdout, p.stderr)
    assert "DONE 40 20" in p.stdout

    p = run_proc(_ELASTIC_CHILD, n_devices=1,
                 env_extra={"FACTOR": "1", "CKPT_DIR": str(tmp_path / "cr"),
                            "OUT_NPY": out_ref})
    assert p.returncode == 0, (p.stdout, p.stderr)
    np.testing.assert_allclose(np.load(out), np.load(out_ref), atol=1e-5)


# ---------------------------------------------------------------------------
# remesh planning
# ---------------------------------------------------------------------------
def test_plan_factors_shapes():
    from repro.distributed import elastic
    assert elastic.plan_factors(8, 1) == (8,)
    assert elastic.plan_factors(8, 2) == (4, 2)
    assert elastic.plan_factors(7, 2) == (7, 1)
    assert int(np.prod(elastic.plan_factors(12, 3))) == 12


def test_validate_stencil_factors_pointed_errors():
    from repro.distributed import elastic
    elastic.validate_stencil_factors((18, 18, 18), (4,), radius=1)
    with pytest.raises(ValueError, match="does not divide"):
        elastic.validate_stencil_factors((18, 18, 18), (5,), radius=1)
    with pytest.raises(ValueError, match="thinner than the ghost ring"):
        elastic.validate_stencil_factors((12, 12, 12), (8,), radius=2)


def test_decompose_gather_roundtrip(rng):
    from repro.distributed import elastic
    g = np.asarray(rng.rand(18, 10), np.float32)
    st = elastic.decompose_fields({"T": g}, (4,), radius=1)
    assert st["T"].shape[0] == 4
    back = elastic.gather_fields(st, (4,), radius=1)
    np.testing.assert_array_equal(back["T"], g)


# ---------------------------------------------------------------------------
# SIGKILL landing DURING an async CheckpointManager.save (kill_at_io):
# LATEST must stay on the previous good step, the torn in-flight step is
# skipped, and resume is bitwise (the PR-6 edge this pins down)
# ---------------------------------------------------------------------------
_ASYNC_SAVE_KILL_CHILD = r"""
import os, numpy as np, jax.numpy as jnp
from repro.core import fd3d, init_parallel_stencil, iterate

ps = init_parallel_stencil(backend="jnp", ndims=3)

@ps.parallel(outputs=("T2",), rotations={"T2": "T"},
             reductions={"err": "max_abs_diff(T2, T)"})
def kern(T2, T, dt):
    return {"T2": fd3d.inn(T) + dt * (fd3d.d2_xi(T) + fd3d.d2_yi(T)
                                      + fd3d.d2_zi(T))}

n = 16
T0 = jnp.zeros((n, n, n), jnp.float32).at[n//2, n//2, n//2].set(1.0)
ck = iterate.Checkpointing(os.environ["CKPT_DIR"], save_every=2,
                           blocking=False)   # ASYNC writer thread
res = iterate.solve_until(kern, dict(T2=T0, T=T0), dict(dt=1e-3),
                          tol=0.0, max_iters=60, check_every=5,
                          checkpoint=ck)
np.save(os.environ["OUT_NPY"], np.asarray(res.fields["T"]))
print("DONE", int(res.iters), res.resumed_from)
"""


def _carry_like(n=16):
    z = np.zeros((n, n, n), np.float32)
    return {"fields": {"T": z, "T2": z},
            "reds": {"err": np.float32(0.0)}, "err": np.float32(0.0)}


def test_kill_during_async_save_leaves_latest_good_and_resumes_bitwise(
        tmp_path):
    ck, out = str(tmp_path / "ck"), str(tmp_path / "out.npy")
    ref = str(tmp_path / "ref.npy")
    env = {"CKPT_DIR": ck, "OUT_NPY": out}

    # each save guards 6 I/O ops (4 tensors + manifest + LATEST swap);
    # op 8 is the 2nd tensor write of the SECOND save -> the process
    # dies inside the async writer with step_20 still a .tmp dir
    plan = fault.FaultPlan(kill_at_io=8)
    p = run_proc(_ASYNC_SAVE_KILL_CHILD,
                 env_extra=dict(env, **{fault.PLAN_ENV: plan.to_env()}))
    assert p.returncode == fault.KILL_EXIT_CODE, (p.stdout, p.stderr)
    assert not os.path.exists(out)

    mgr = CheckpointManager(ck)
    assert mgr.latest_step() == 10          # LATEST: previous good step
    assert mgr.list_steps() == [10]         # torn step not listed
    assert os.path.isdir(mgr.step_dir(20) + ".tmp")  # the wreck

    # resume (no plan): picks up from 10 and completes
    p = run_proc(_ASYNC_SAVE_KILL_CHILD, env_extra=env)
    assert p.returncode == 0, (p.stdout, p.stderr)
    assert "DONE 60 10" in p.stdout

    # uninterrupted reference in a fresh process: bitwise equal
    p = run_proc(_ASYNC_SAVE_KILL_CHILD,
                 env_extra={"CKPT_DIR": str(tmp_path / "ck_ref"),
                            "OUT_NPY": ref})
    assert p.returncode == 0, (p.stdout, p.stderr)
    np.testing.assert_array_equal(np.load(out), np.load(ref))


def test_torn_inflight_step_promoted_by_storage_is_skipped_corrupt(
        tmp_path):
    """The uglier crash window: the storage layer completed the rename
    and LATEST update but the tensor data never hit the platter (write
    reordering on power cut). restore(step=None) must walk past the torn
    step, record it in skipped_corrupt, and land on the previous good
    one; a checkpointed solve resumes from it bitwise."""
    ck, out = str(tmp_path / "ck"), str(tmp_path / "out.npy")
    ref = str(tmp_path / "ref.npy")
    env = {"CKPT_DIR": ck, "OUT_NPY": out}
    plan = fault.FaultPlan(kill_at_io=8)
    p = run_proc(_ASYNC_SAVE_KILL_CHILD,
                 env_extra=dict(env, **{fault.PLAN_ENV: plan.to_env()}))
    assert p.returncode == fault.KILL_EXIT_CODE, (p.stdout, p.stderr)

    # simulate the reordered-storage outcome: the torn dir appears
    # completed and LATEST names it
    mgr = CheckpointManager(ck)
    os.rename(mgr.step_dir(20) + ".tmp", mgr.step_dir(20))
    with open(os.path.join(ck, "LATEST"), "w") as f:
        f.write(os.path.basename(mgr.step_dir(20)))

    assert mgr.latest_step() == 20
    tree, extra = mgr.restore(_carry_like())
    assert extra["step"] == 10
    assert [s for s, _ in extra["skipped_corrupt"]] == [20]

    # the checkpointed solve takes the same fallback and stays bitwise
    p = run_proc(_ASYNC_SAVE_KILL_CHILD, env_extra=env)
    assert p.returncode == 0, (p.stdout, p.stderr)
    assert "DONE 60 10" in p.stdout
    p = run_proc(_ASYNC_SAVE_KILL_CHILD,
                 env_extra={"CKPT_DIR": str(tmp_path / "ck_ref"),
                            "OUT_NPY": ref})
    assert p.returncode == 0, (p.stdout, p.stderr)
    np.testing.assert_array_equal(np.load(out), np.load(ref))
