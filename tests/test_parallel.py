"""The @parallel engine: backend equivalence, math-close vs explicit
notation (paper §3 E2), launch-parameter derivation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Grid, FieldSet, fd2d, fd3d, init_parallel_stencil
from repro.kernels import ref
from repro.kernels.stencil import (LaunchFootprintError, derive_launch,
                                   preflight_vmem)


def _diffusion_kernels(fd):
    def math_close(T2, T, Ci, lam, dt, _dx, _dy, _dz):
        return {"T2": fd.inn(T) + dt * (lam * fd.inn(Ci) * (
            fd.d2_xi(T) * _dx ** 2 + fd.d2_yi(T) * _dy ** 2 +
            fd.d2_zi(T) * _dz ** 2))}

    def explicit(T2, T, Ci, lam, dt, _dx, _dy, _dz):
        c = T[1:-1, 1:-1, 1:-1]
        lap = ((T[2:, 1:-1, 1:-1] - 2 * c + T[:-2, 1:-1, 1:-1]) * _dx ** 2
               + (T[1:-1, 2:, 1:-1] - 2 * c + T[1:-1, :-2, 1:-1]) * _dy ** 2
               + (T[1:-1, 1:-1, 2:] - 2 * c + T[1:-1, 1:-1, :-2]) * _dz ** 2)
        return {"T2": c + dt * (lam * Ci[1:-1, 1:-1, 1:-1] * lap)}

    return math_close, explicit


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("notation", ["math_close", "explicit"])
def test_backends_and_notations_match_oracle(backend, notation, rng):
    shape = (24, 16, 32)
    T = jnp.asarray(rng.rand(*shape), jnp.float32)
    Ci = jnp.asarray(rng.rand(*shape) + 0.5, jnp.float32)
    dt, lam = 1e-4, 1.0
    inv = tuple(float(s - 1) for s in shape)
    ps = init_parallel_stencil(backend=backend, ndims=3)
    mc, ex = _diffusion_kernels(fd3d)
    kern = ps.parallel(outputs=("T2",))(mc if notation == "math_close" else ex)
    got = kern(T2=T, T=T, Ci=Ci, lam=lam, dt=dt, _dx=inv[0], _dy=inv[1],
               _dz=inv[2])
    want = ref.diffusion3d_step(T, T, Ci, lam, dt, *inv)
    np.testing.assert_allclose(got, want, atol=1e-6, rtol=1e-6)


def test_2d_kernel_both_backends(rng):
    shape = (32, 48)
    U = jnp.asarray(rng.rand(*shape), jnp.float32)

    def kern(U2, U, dt):
        return {"U2": fd2d.inn(U) + dt * (fd2d.d2_xi(U) + fd2d.d2_yi(U))}

    outs = []
    for backend in ("jnp", "pallas"):
        ps = init_parallel_stencil(backend=backend, ndims=2)
        k = ps.parallel(outputs=("U2",))(kern)
        outs.append(np.asarray(k(U2=U, U=U, dt=1e-3)))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-6)


def test_multi_output_kernel(rng):
    shape = (16, 16, 16)
    A = jnp.asarray(rng.rand(*shape), jnp.float32)
    B = jnp.asarray(rng.rand(*shape), jnp.float32)

    def kern(A2, B2, A, B, dt):
        return {"A2": fd3d.inn(A) + dt * fd3d.inn(B),
                "B2": fd3d.inn(B) - dt * fd3d.inn(A)}

    for backend in ("jnp", "pallas"):
        ps = init_parallel_stencil(backend=backend, ndims=3)
        k = ps.parallel(outputs=("A2", "B2"))(kern)
        outs = k(A2=A, B2=B, A=A, B=B, dt=0.1)
        np.testing.assert_allclose(outs["A2"][1:-1, 1:-1, 1:-1],
                                   fd3d.inn(A) + 0.1 * fd3d.inn(B), atol=1e-6)
        np.testing.assert_allclose(outs["B2"][1:-1, 1:-1, 1:-1],
                                   fd3d.inn(B) - 0.1 * fd3d.inn(A), atol=1e-6)


def test_time_loop_equivalence(rng):
    """Several steps of the full solver: pallas == jnp == oracle."""
    g = Grid((16, 16, 16))
    fs = FieldSet(g)
    T0 = fs.from_fn(lambda x, y, z: jnp.exp(-((x - .5) ** 2 + (y - .5) ** 2 +
                                              (z - .5) ** 2) / 0.02))
    Ci = fs.ones() / 2.0
    lam = 1.0
    dt = g.stable_diffusion_dt(lam / 0.5)
    inv = g.inv_spacing
    mc, _ = _diffusion_kernels(fd3d)
    results = {}
    for backend in ("jnp", "pallas"):
        ps = init_parallel_stencil(backend=backend, ndims=3)
        k = ps.parallel(outputs=("T2",))(mc)
        T, T2 = T0, T0
        for _ in range(5):
            T2 = k(T2=T2, T=T, Ci=Ci, lam=lam, dt=dt, _dx=inv[0], _dy=inv[1],
                   _dz=inv[2])
            T, T2 = T2, T
        results[backend] = np.asarray(T)
    np.testing.assert_allclose(results["jnp"], results["pallas"], atol=2e-6)


def test_derive_launch_divides_and_fits():
    for shape in [(512, 512, 512), (96, 64, 384), (17, 34, 51)]:
        grid, block = derive_launch(shape, radius=1, n_fields=3, itemsize=4)
        assert all(s % b == 0 for s, b in zip(shape, block))
        window = 3 * np.prod([b + 2 for b in block]) * 4
        assert window <= 8 << 20
        assert all(g * b == s for g, b, s in zip(grid, block, shape))


def test_derive_launch_respects_tile_override():
    grid, block = derive_launch((64, 64, 64), 1, 3, 4, tile=(8, 8, 64))
    assert block == (8, 8, 64) and grid == (8, 8, 1)
    with pytest.raises(ValueError):
        derive_launch((64, 64, 64), 1, 3, 4, tile=(7, 8, 64))
    with pytest.raises(ValueError):  # rank mismatch
        derive_launch((64, 64, 64), 1, 3, 4, tile=(8, 64))


def test_derive_launch_vmem_budget_shrinks_blocks():
    """A tighter budget must shrink the halo-extended working set while the
    blocks keep dividing the array extents."""
    shape = (256, 256, 256)
    big = 8 << 20
    small = 1 << 20
    _, b_big = derive_launch(shape, 1, 3, 4, vmem_budget=big)
    _, b_small = derive_launch(shape, 1, 3, 4, vmem_budget=small)

    def window(blk, halo=1):
        return 3 * np.prod([b + 2 * halo for b in blk]) * 4

    assert window(b_small) <= small
    assert window(b_small) < window(b_big)
    assert all(s % b == 0 for s, b in zip(shape, b_small))


def test_preflight_rejects_oversized_explicit_tile():
    """An explicit tile whose halo-extended windows exceed device VMEM
    must fail at derivation time with a pointed admission error, not as
    an opaque backend allocation failure later."""
    shape = (512, 512, 512)
    with pytest.raises(LaunchFootprintError) as ei:
        derive_launch(shape, 1, 3, 4, tile=(512, 512, 512))
    msg = str(ei.value)
    assert "explicit tile" in msg and "MiB" in msg
    assert "REPRO_VMEM_LIMIT_BYTES" in msg
    # the same footprint is admitted when the device really has the room
    derive_launch(shape, 1, 3, 4, tile=(512, 512, 512),
                  vmem_limit=4 << 30)
    # LaunchFootprintError IS a ValueError: existing callers' handlers hold
    assert issubclass(LaunchFootprintError, ValueError)


def test_preflight_env_override(monkeypatch):
    tile = (64, 64, 64)
    window = 3 * int(np.prod([b + 2 for b in tile])) * 4
    monkeypatch.setenv("REPRO_VMEM_LIMIT_BYTES", str(window - 1))
    with pytest.raises(LaunchFootprintError):
        derive_launch((64, 64, 64), 1, 3, 4, tile=tile)
    monkeypatch.setenv("REPRO_VMEM_LIMIT_BYTES", str(window))
    derive_launch((64, 64, 64), 1, 3, 4, tile=tile)
    # explicit argument beats the env override
    with pytest.raises(LaunchFootprintError):
        preflight_vmem(tile, window, vmem_limit=window - 1,
                       explicit_tile=True)


def test_preflight_normal_derivation_passes():
    # auto-derived blocks honor the SOFT budget (8 MiB), far under the
    # hard limit — derivation never trips the admission check on its own
    for shape in [(512, 512, 512), (96, 64, 384), (17, 34, 51)]:
        derive_launch(shape, radius=1, n_fields=3, itemsize=4)


def test_derive_launch_alignment_preferences():
    """Minor axis prefers 128-lane multiples, next-to-minor 8-sublane
    multiples, whenever the extents allow it."""
    _, block = derive_launch((64, 64, 256), 1, 3, 4)
    assert block[-1] % 128 == 0
    assert block[-2] % 8 == 0
    # extents with no aligned divisor still yield a valid launch
    grid, block = derive_launch((17, 34, 51), 1, 3, 4)
    assert all(g * b == s for g, b, s in zip(grid, block, (17, 34, 51)))


def test_derive_launch_nsteps_halo_arithmetic():
    """Temporal blocking widens the VMEM halo to nsteps*radius: the same
    budget must yield a window set that still fits, and the halo term in
    the working set follows k*r."""
    shape = (256, 256, 256)
    budget = 2 << 20
    for radius, nsteps in [(1, 2), (1, 4), (2, 2)]:
        grid, block = derive_launch(shape, radius, 3, 4, vmem_budget=budget,
                                    nsteps=nsteps)
        halo = radius * nsteps
        window = 3 * np.prod([b + 2 * halo for b in block]) * 4
        assert window <= budget, (radius, nsteps, block)
        assert all(s % b == 0 for s, b in zip(shape, block))
    # deeper blocking can only shrink (or keep) the block volume
    _, b1 = derive_launch(shape, 1, 3, 4, vmem_budget=budget, nsteps=1)
    _, b4 = derive_launch(shape, 1, 3, 4, vmem_budget=budget, nsteps=4)
    assert np.prod(b4) <= np.prod(b1)


def test_launch_info_exposed(rng):
    ps = init_parallel_stencil(backend="pallas", ndims=2)

    @ps.parallel(outputs=("U2",))
    def k(U2, U):
        return {"U2": fd2d.inn(U) * 2.0}

    U = jnp.asarray(rng.rand(16, 128), jnp.float32)
    k(U2=U, U=U)
    info = list(k.launch_info.values())[0]
    assert info["grid"] and info["block"] and info["window_bytes"] > 0
